//! Pluggable serving-path backends — the engine-facing attention/cache
//! interface.
//!
//! The coordinator used to hard-code two paths (`match PathMode` in three
//! places, per-path session fields `Option<KvCache>` /
//! `Option<(Vec<f32>, Vec<f32>)>` living side by side in every session).
//! This module follows the FlashInfer lesson — a serving engine stays
//! fast and extensible when attention paths are *composable* behind one
//! interface — and collapses each path into an [`AttentionBackend`]:
//!
//! * [`AttentionBackend::prefill`] runs the prompt and builds the
//!   backend's own session state (`Self::Session`): cache, slabs,
//!   whatever the path needs.
//! * [`AttentionBackend::decode_step`] produces logits + the new token's
//!   K/V for one position, reading the session's cache views.
//! * [`AttentionBackend::fold_new_token`] absorbs the new K/V into the
//!   session state.
//!
//! Adding a third path (mixed-precision cache, exact-softmax turbo, a
//! speculative path) is one impl in one file — the engine never changes.
//! [`TurboCpuBackend`] proves the claim: the pure-Rust CPU substrate
//! (integer kernels + `turbo_decode_streams` + [`CpuModel`]) became a
//! serving path without touching `Engine::step`.
//!
//! [`TurboBackend`] is where the paper's decode economics are enforced:
//! its session owns persistent executable-layout slabs
//! ([`TurboSlabs`]) kept in sync *incrementally* from each stream's
//! [`Q1View`](crate::kvcache::Q1View). Each immutable q2 page is
//! dequantized exactly once when it appears; a decode step then does
//! O(new tokens) cache work instead of the O(layers * heads * context *
//! d_head) full rematerialization the previous `decode_turbo` performed
//! on every generated token.
//!
//! The engine selects a backend at runtime from [`PathMode`], so the
//! associated-type trait is wrapped by the object-safe [`DynBackend`]
//! erasure (session state behind [`BackendState`]); the only
//! mode-`match` left in the crate is the constructor [`backend_for`].

use std::any::Any;
use std::sync::Arc;

use anyhow::Result;

use crate::attention::turbo::DecodeScratch;
use crate::coordinator::prefix::SharedPrefix;
use crate::kvcache::{
    CacheStats, HeadCacheMut, KvCache, KvCacheConfig, PagePool, PrecisionMap,
    SharedPagePool,
};
use crate::model::{
    CpuModel, DecodeOut, FlashSlabs, ModelBundle, ModelScratch, PrefillCursor,
    SlabShardMut, TurboSlabs,
};
use crate::pool::{balanced_chunk_sizes, WorkerPool};
use crate::quant::Bits;
use crate::runtime::ModelInfo;

/// Which attention path serves requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PathMode {
    /// TurboAttention: quantized execution + paged q2 cache, attention
    /// inside the `decode_turbo` AOT executable.
    Turbo,
    /// TurboAttention on the pure-Rust CPU substrate: the same paged q2
    /// cache and slabs, but prefill/decode attention runs through the
    /// integer kernels (`turbo_decode_streams`) on the worker pool and
    /// the model is the deterministic [`CpuModel`] — no artifacts, no
    /// PJRT toolchain.
    TurboCpu,
    /// Exact FlashAttention baseline with an FP32 cache.
    Flash,
}

/// Result of one [`AttentionBackend::prefill_chunk`] grant.
pub enum PrefillChunkOut<S> {
    /// The grant was consumed but the prompt is not finished; the
    /// cursor passed in holds the resume state.
    Pending {
        /// Prompt tokens processed so far, across all grants.
        processed: usize,
    },
    /// Prefill completed: the logits row of the final prompt position
    /// (the first generated token samples from it), the fresh session,
    /// and the prefix-registration handles — exactly what
    /// [`AttentionBackend::prefill`] would have produced.
    Done {
        last_logits: Vec<f32>,
        session: S,
        reg: Option<SharedPrefix>,
    },
}

/// One serving path: prompt prefill, per-token decode, and K/V fold, with
/// the per-session cache state owned by the backend's `Session` type.
pub trait AttentionBackend {
    /// Per-request state (caches, slabs, sync cursors) — created by
    /// `prefill`, threaded through `decode_step`/`fold_new_token`.
    type Session;

    fn name(&self) -> &'static str;

    /// Run prefill over `prompt`; returns the full prefill logits buffer
    /// (`[max_ctx * vocab]`, see `ModelBundle::logits_at`), a fresh
    /// session, and — on paths with a shared page pool — the session's
    /// page-aligned prompt-prefix handles for prefix-index registration.
    ///
    /// `shared`, when given, is a page-aligned prefix of `prompt` whose
    /// pooled q2 pages an earlier session already built: the new session
    /// forks from those pages (retaining them) and prefill stores only
    /// the tail. The decode buffer is never shared (it is mutable), and
    /// backends without a page pool ignore `shared` and register
    /// nothing.
    fn prefill(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
    ) -> Result<(Vec<f32>, Self::Session, Option<SharedPrefix>)>;

    /// One decode step: feed `token` at absolute position `pos`, attend
    /// over the session's cache.
    ///
    /// `sparse_topk_pages` is the per-request bandwidth knob: `0` is the
    /// dense path; `k > 0` asks the backend to exactly attend only the
    /// `k` highest-envelope-scored full pages per stream and fold each
    /// skipped page's mass as one mean-value term (SparQ-style). The
    /// contract every implementation must keep: `k = 0` and
    /// `k >= pages` are **bit-identical** to dense, and selection is
    /// deterministic (ties break toward the lower page index).
    /// Backends without a sparse path ignore the knob and stay dense.
    fn decode_step(
        &self,
        bundle: &mut ModelBundle,
        session: &mut Self::Session,
        token: u8,
        pos: usize,
        sparse_topk_pages: usize,
    ) -> Result<DecodeOut>;

    /// Fold the new token's K/V (`[L*H*dh]` each) into the session cache.
    fn fold_new_token(
        &self,
        bundle: &ModelBundle,
        session: &mut Self::Session,
        k_new: &[f32],
        v_new: &[f32],
        pos: usize,
    );

    /// Cache memory statistics, if the path has a compressed cache.
    fn cache_stats(&self, session: &Self::Session) -> Option<CacheStats>;

    /// The refcounted page pool every session of this backend stores
    /// its flushed q2 pages in, if the path has one — what admission
    /// uses for prefix lookups and the engine for dedup metrics.
    fn page_pool(&self) -> Option<&SharedPagePool> {
        None
    }

    /// Whether [`AttentionBackend::prefill_chunk`] can actually stop at
    /// a chunk boundary and resume later. Backends that keep the
    /// default `false` always run the whole prompt in one grant, and
    /// the scheduler clamps its chunk size to whole-prompt grants for
    /// them.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Run at most `max_tokens` further prompt tokens of a resumable
    /// prefill. `cursor` is the type-erased mid-prefill state: `None`
    /// opens a new prefill (the only time `shared` is consulted),
    /// `Some` resumes one. On completion the cursor is consumed and
    /// [`PrefillChunkOut::Done`] carries the final prompt position's
    /// logits row plus the fresh session, bit-for-bit what a one-shot
    /// [`prefill`] builds — chunking must be invisible in the output.
    ///
    /// The default is the non-resumable path: one call, whole prompt,
    /// delegated to [`prefill`].
    ///
    /// [`prefill`]: AttentionBackend::prefill
    fn prefill_chunk(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
        cursor: &mut Option<BackendState>,
        _max_tokens: usize,
    ) -> Result<PrefillChunkOut<Self::Session>> {
        debug_assert!(
            cursor.is_none(),
            "backend without chunked prefill handed a resume cursor"
        );
        let (logits, session, reg) = self.prefill(bundle, prompt, shared)?;
        let last_logits = bundle.logits_at(&logits, prompt.len() - 1).to_vec();
        Ok(PrefillChunkOut::Done { last_logits, session, reg })
    }
}

// ---------------------------------------------------------------------------
// Turbo path
// ---------------------------------------------------------------------------

/// TurboAttention serving path: INT8 execution over the paged q2 cache,
/// with per-(layer, head) cache sync fanned out on a shared worker pool.
#[derive(Clone)]
pub struct TurboBackend {
    /// q2 storage width for uniform precision.
    pub kv_bits: Bits,
    /// Number of 2-bit heads per layer (0 = uniform `kv_bits`).
    pub n_2bit_heads: usize,
    /// Decode worker pool, shared by every session this backend creates
    /// (a 1-thread pool is the exact serial path).
    pool: Arc<WorkerPool>,
    /// Refcounted q2 page store shared by every session — prefix-
    /// sharing sessions fork from it.
    pages: SharedPagePool,
}

impl TurboBackend {
    pub fn new(
        kv_bits: Bits,
        n_2bit_heads: usize,
        pool: Arc<WorkerPool>,
    ) -> TurboBackend {
        TurboBackend {
            kv_bits,
            n_2bit_heads,
            pool,
            pages: PagePool::new_shared(),
        }
    }
}

/// Turbo per-request state: the paged cache plus persistent decode slabs
/// and the cursors tracking how much of the cache they already mirror.
pub struct TurboSession {
    pub cache: KvCache,
    pub slabs: TurboSlabs,
    /// Worker pool the slab sync forks onto (serial when 1 thread).
    pool: Arc<WorkerPool>,
    /// Pages already copied into the slabs (uniform across streams — all
    /// (layer, head, K/V) streams advance in lockstep).
    synced_pages: usize,
    /// Buffer tokens already copied after the page region.
    synced_buf: usize,
    /// Pages whose sparse summaries (kmin/kmax/vmean) the slabs already
    /// mirror. Tracked separately from `synced_pages` because summaries
    /// are only materialized for sparse decode sessions — a session
    /// that turns sparse after dense syncs backfills from here.
    synced_summary_pages: usize,
}

impl TurboSession {
    pub fn new(
        cache: KvCache,
        bundle: &ModelBundle,
        pool: Arc<WorkerPool>,
    ) -> TurboSession {
        let slabs = bundle.new_turbo_slabs();
        TurboSession::from_parts_pooled(cache, slabs, pool)
    }

    /// Assemble from pre-built parts (tests/benches that have no PJRT
    /// bundle), on the serial path.
    pub fn from_parts(cache: KvCache, slabs: TurboSlabs) -> TurboSession {
        TurboSession::from_parts_pooled(
            cache,
            slabs,
            Arc::new(WorkerPool::new(1)),
        )
    }

    /// [`Self::from_parts`] with an explicit decode pool.
    pub fn from_parts_pooled(
        cache: KvCache,
        slabs: TurboSlabs,
        pool: Arc<WorkerPool>,
    ) -> TurboSession {
        TurboSession {
            cache,
            slabs,
            pool,
            synced_pages: 0,
            synced_buf: 0,
            synced_summary_pages: 0,
        }
    }

    /// The pool this session's decode work forks onto.
    pub fn pool(&self) -> &Arc<WorkerPool> {
        &self.pool
    }

    /// Copy tokens materialized since the last call from every stream's
    /// incremental q1 view into the executable-layout slabs, and return
    /// the valid token count `nk`.
    ///
    /// Cost is O(new tokens * layers * heads * d_head) — amortized O(1)
    /// per generated token per stream — because `q1_view` dequantizes
    /// each immutable page exactly once and the copy below starts at the
    /// first token the slabs don't already hold. (A buffer flush converts
    /// mirrored buffer tokens into a page, so the restart point falls
    /// back to that page's boundary, never to zero.)
    ///
    /// The per-(layer, head) copies are independent — headwise
    /// quantization gives every stream its own pages, scales, and slab
    /// shard — so they fork onto the session's pool: each worker gets a
    /// disjoint contiguous group of `&mut` stream pairs
    /// ([`KvCache::streams_mut`]) with their slab shards
    /// ([`TurboSlabs::shards_mut`]). Results are bit-identical for
    /// every thread count (the parallel-parity suite enforces it). On a
    /// worker panic the cursors stay put, so the next successful sync
    /// rewrites everything the failed one may have half-written.
    pub fn sync_slabs(&mut self) -> Result<usize> {
        self.sync_slabs_sparse(false)
    }

    /// [`Self::sync_slabs`] with the sparse-path switch: when
    /// `with_summaries` is set, every flushed page's pool summary
    /// (per-channel K min/max envelope + V column mean) is also copied
    /// into the slabs' `kmin`/`kmax`/`vmean` arrays, tracked by its own
    /// cursor so a session that mixes dense and sparse syncs backfills
    /// correctly. Dense sessions never pay for summaries.
    pub fn sync_slabs_sparse(&mut self, with_summaries: bool) -> Result<usize> {
        let l_n = self.cache.cfg.n_layers;
        let h_n = self.cache.cfg.n_heads;
        let dh = self.cache.cfg.d_head;
        let block = self.cache.cfg.block;
        let n_streams = l_n * h_n;
        if n_streams == 0 {
            return Ok(0);
        }
        let c = self.slabs.k8.len() / (n_streams * dh);
        let nb = self.slabs.sk.len() / n_streams;
        debug_assert_eq!(nb, c / block);
        // All streams advance in lockstep; probe (0, 0) K for the delta.
        let (pages_now, buf_now) = {
            let s = self.cache.head(0, 0);
            (s.k.pages.len(), s.k.buffer.len())
        };
        let nk = pages_now * block + buf_now;
        let start = if pages_now > self.synced_pages {
            // New pages exist; the old mirrored buffer tail was flushed
            // into the first of them — recopy from that boundary.
            self.synced_pages * block
        } else {
            pages_now * block + self.synced_buf
        };
        let start = start.min(nk);
        // Page range whose sparse summaries need mirroring this sync
        // (empty on dense syncs and when already up to date).
        let (sum_p0, sum_p1) = if with_summaries {
            (self.synced_summary_pages.min(pages_now), pages_now)
        } else {
            (0, 0)
        };
        let pool = Arc::clone(&self.pool);
        // Deal streams into <= threads contiguous groups (sizes differ
        // by at most one, `balanced_chunk_sizes`): steady-state sync
        // copies ~one token per stream, so per-stream jobs would drown
        // in dispatch overhead. A single group — the 1-thread pool's
        // exact old serial loop — moves the whole iterator into one
        // inline job, allocating nothing.
        let jobs = pool.threads().min(n_streams);
        let mut shards =
            self.cache.streams_mut().zip(self.slabs.shards_mut(n_streams));
        let mut forked = 0usize;
        pool.scope(|scope| {
            if jobs == 1 {
                let forked = &mut forked;
                scope.execute(move || {
                    for (streams, shard) in shards {
                        *forked += 1;
                        sync_stream_shard(
                            streams, shard, start, nk, dh, block, nb, sum_p0,
                            sum_p1,
                        );
                    }
                });
                return;
            }
            for len in balanced_chunk_sizes(n_streams, jobs) {
                let group: Vec<_> = shards.by_ref().take(len).collect();
                forked += group.len();
                scope.execute(move || {
                    for (streams, shard) in group {
                        sync_stream_shard(
                            streams, shard, start, nk, dh, block, nb, sum_p0,
                            sum_p1,
                        );
                    }
                });
            }
        })?;
        // The zip would silently truncate if the slabs were built for a
        // different geometry than the cache — that must be loud, or
        // decode would read stale codes for the skipped streams.
        assert_eq!(
            forked, n_streams,
            "cache/slab geometry mismatch: {forked} shards for {n_streams} streams"
        );
        self.synced_pages = pages_now;
        self.synced_buf = buf_now;
        if with_summaries {
            self.synced_summary_pages = pages_now;
        }
        Ok(nk)
    }
}

/// Per-worker body of [`TurboSession::sync_slabs`]: bring one stream
/// pair's q1 views up to date and copy the `[start, nk)` token range
/// (plus live scales) into the stream's slab shard. Pages
/// `[sum_p0, sum_p1)` additionally mirror their pool summaries into the
/// shard's sparse arrays (the range is empty on dense syncs).
#[allow(clippy::too_many_arguments)]
fn sync_stream_shard(
    streams: HeadCacheMut<'_>,
    shard: SlabShardMut<'_>,
    start: usize,
    nk: usize,
    dh: usize,
    block: usize,
    nb: usize,
    sum_p0: usize,
    sum_p1: usize,
) {
    let nbv = nk.div_ceil(block).min(nb);
    let (codes, scales, n) = streams.k.q1_view();
    debug_assert_eq!(n, nk, "streams out of lockstep");
    shard.k8[start * dh..nk * dh]
        .copy_from_slice(&codes[start * dh..nk * dh]);
    shard.sk[..nbv].copy_from_slice(&scales[..nbv]);
    let (codes, scales, n) = streams.v.q1_view();
    debug_assert_eq!(n, nk, "streams out of lockstep");
    shard.v8[start * dh..nk * dh]
        .copy_from_slice(&codes[start * dh..nk * dh]);
    shard.sv[..nbv].copy_from_slice(&scales[..nbv]);
    if sum_p0 < sum_p1 {
        // K and V streams store their pages in the same shared pool;
        // one read lock covers both (the lazy summary memo fill is
        // `&self`-safe under it, like the q1 memos).
        let pool = streams.k.page_pool().read().expect("page pool");
        for pi in sum_p0..sum_p1 {
            let s = pool.summary(streams.k.pages[pi]);
            shard.kmin[pi * dh..(pi + 1) * dh].copy_from_slice(&s.min);
            shard.kmax[pi * dh..(pi + 1) * dh].copy_from_slice(&s.max);
            let s = pool.summary(streams.v.pages[pi]);
            shard.vmean[pi * dh..(pi + 1) * dh].copy_from_slice(&s.mean);
        }
    }
}

/// Build the paged q2 cache for one request from a precision policy and
/// the model geometry — shared by every turbo-family backend. Pages go
/// into `pages`, the backend's shared refcounted pool.
#[allow(clippy::too_many_arguments)]
fn turbo_cache_for(
    l_n: usize,
    h_n: usize,
    d_head: usize,
    block: usize,
    kv_bits: Bits,
    n_2bit_heads: usize,
    pages: SharedPagePool,
) -> KvCache {
    let precision = if n_2bit_heads == 0 {
        PrecisionMap::uniform(l_n, h_n, kv_bits)
    } else {
        // Static head split until calibration runs (experiments use
        // `PrecisionMap::mixed_from_stats` with real stats).
        let mut pm = PrecisionMap::uniform(l_n, h_n, Bits::Int4);
        for l in 0..l_n {
            for h in 0..n_2bit_heads.min(h_n) {
                pm.set(l, h, Bits::Int2);
            }
        }
        pm
    };
    KvCache::with_pool(
        KvCacheConfig::new(l_n, h_n, d_head, block, precision),
        pages,
    )
}

/// Retain a shared prompt prefix's pooled pages into a fresh cache —
/// the fork point of prefix sharing. Only immutable q2 pages are
/// shared; each stream's mutable decode buffer stays private, and the
/// adopted pages form the page-aligned head of every stream.
fn adopt_shared_prefix(cache: &mut KvCache, shared: &SharedPrefix) {
    let l_n = cache.cfg.n_layers;
    let h_n = cache.cfg.n_heads;
    assert_eq!(
        shared.n_streams,
        l_n * h_n,
        "shared prefix geometry mismatch"
    );
    assert_eq!(
        shared.tokens,
        shared.n_pages * cache.cfg.block,
        "shared prefix must be whole pages"
    );
    for l in 0..l_n {
        for h in 0..h_n {
            let s = l * h_n + h;
            cache.k_stream_mut(l, h).adopt_pages(shared.k_pages(s));
            cache.v_stream_mut(l, h).adopt_pages(shared.v_pages(s));
        }
    }
}

/// Collect a freshly prefilled cache's page-aligned prompt-prefix
/// handles for prefix-index registration (weak — no retains; the
/// session's own refs keep the pages alive while it runs, and forks
/// that adopt them extend that lifetime).
fn collect_prefix(cache: &KvCache, prompt_len: usize) -> Option<SharedPrefix> {
    let block = cache.cfg.block;
    let n_pages = prompt_len / block;
    if n_pages == 0 {
        return None;
    }
    let l_n = cache.cfg.n_layers;
    let h_n = cache.cfg.n_heads;
    let mut k = Vec::with_capacity(l_n * h_n * n_pages);
    let mut v = Vec::with_capacity(l_n * h_n * n_pages);
    for l in 0..l_n {
        for h in 0..h_n {
            let hc = cache.head(l, h);
            debug_assert!(hc.k.pages.len() >= n_pages, "prefill short");
            k.extend_from_slice(&hc.k.pages[..n_pages]);
            v.extend_from_slice(&hc.v.pages[..n_pages]);
        }
    }
    Some(SharedPrefix {
        tokens: n_pages * block,
        n_pages,
        n_streams: l_n * h_n,
        k,
        v,
    })
}

/// Append one decoded token's K/V (`[L*H*dh]`, layer-major) to every
/// stream of a turbo-family paged cache.
fn fold_kv_into_cache(cache: &mut KvCache, k_new: &[f32], v_new: &[f32]) {
    let l_n = cache.cfg.n_layers;
    let h_n = cache.cfg.n_heads;
    let dh = cache.cfg.d_head;
    for l in 0..l_n {
        for h in 0..h_n {
            let o = (l * h_n + h) * dh;
            cache.k_stream_mut(l, h).push_token(&k_new[o..o + dh]);
            cache.v_stream_mut(l, h).push_token(&v_new[o..o + dh]);
        }
    }
}

impl TurboBackend {
    /// Build the paged cache for one request from this backend's
    /// precision policy and the model geometry.
    fn new_cache(&self, bundle: &ModelBundle) -> KvCache {
        turbo_cache_for(
            bundle.n_layers(),
            bundle.n_heads(),
            bundle.d_head(),
            bundle.block(),
            self.kv_bits,
            self.n_2bit_heads,
            Arc::clone(&self.pages),
        )
    }
}

impl AttentionBackend for TurboBackend {
    type Session = TurboSession;

    fn name(&self) -> &'static str {
        "turbo"
    }

    fn prefill(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
    ) -> Result<(Vec<f32>, TurboSession, Option<SharedPrefix>)> {
        let out = bundle.prefill(prompt, true)?;
        let (k8, v8, sk, sv) =
            out.turbo_cache.expect("turbo prefill returns cache");
        let mut cache = self.new_cache(bundle);
        let skip = match shared {
            Some(sp) => {
                debug_assert!(sp.tokens <= prompt.len());
                adopt_shared_prefix(&mut cache, sp);
                sp.tokens
            }
            None => 0,
        };
        bundle.ingest_prefill_from(
            &mut cache,
            &k8,
            &v8,
            &sk,
            &sv,
            prompt.len(),
            skip,
        );
        let reg = collect_prefix(&cache, prompt.len());
        let session =
            TurboSession::new(cache, bundle, Arc::clone(&self.pool));
        Ok((out.logits, session, reg))
    }

    fn decode_step(
        &self,
        bundle: &mut ModelBundle,
        session: &mut TurboSession,
        token: u8,
        pos: usize,
        _sparse_topk_pages: usize,
    ) -> Result<DecodeOut> {
        // The AOT executable has no sparse kernel: this path stays dense
        // regardless of the knob (documented on the trait method).
        let nk = session.sync_slabs()?;
        bundle.decode_turbo(&mut session.slabs, token, pos, nk)
    }

    fn fold_new_token(
        &self,
        _bundle: &ModelBundle,
        session: &mut TurboSession,
        k_new: &[f32],
        v_new: &[f32],
        _pos: usize,
    ) {
        fold_kv_into_cache(&mut session.cache, k_new, v_new);
    }

    fn cache_stats(&self, session: &TurboSession) -> Option<CacheStats> {
        let mut stats = session.cache.stats();
        stats.slab_bytes = session.slabs.bytes();
        Some(stats)
    }

    fn page_pool(&self) -> Option<&SharedPagePool> {
        Some(&self.pages)
    }
}

// ---------------------------------------------------------------------------
// TurboCpu path (pure-Rust substrate, no artifacts)
// ---------------------------------------------------------------------------

/// The ROADMAP's third `AttentionBackend`: TurboAttention served
/// **entirely on the CPU substrate**. Prefill runs per-head
/// [`turbo_attention`](crate::attention::turbo_attention) tiles and
/// decode runs
/// [`turbo_decode_streams`](crate::attention::turbo_decode_streams)
/// over the session's q1 slabs — both on the integer micro-kernels
/// ([`crate::kernels`]) and the shared worker pool — with the
/// deterministic [`CpuModel`] supplying everything around attention. No
/// `decode_turbo` executable, no PJRT client, no artifacts: the
/// quantized-execution hot path is exercised end to end by the engine,
/// the parity suite, and `decode_bench`.
pub struct TurboCpuBackend {
    /// q2 storage width for uniform precision.
    pub kv_bits: Bits,
    /// Number of 2-bit heads per layer (0 = uniform `kv_bits`).
    pub n_2bit_heads: usize,
    /// The deterministic CPU model, shared by every session (weights
    /// are immutable).
    model: Arc<CpuModel>,
    /// Decode worker pool shared by every session this backend creates.
    pool: Arc<WorkerPool>,
    /// Refcounted q2 page store shared by every session.
    pages: SharedPagePool,
}

impl TurboCpuBackend {
    /// Build the backend (and its deterministic model) for a geometry.
    pub fn new(
        info: &ModelInfo,
        seed: u64,
        kv_bits: Bits,
        n_2bit_heads: usize,
        pool: Arc<WorkerPool>,
    ) -> TurboCpuBackend {
        TurboCpuBackend {
            kv_bits,
            n_2bit_heads,
            model: Arc::new(CpuModel::new(info, seed)),
            pool,
            pages: PagePool::new_shared(),
        }
    }

    /// The backend's model (tests inspect geometry/seed).
    pub fn model(&self) -> &Arc<CpuModel> {
        &self.model
    }

    /// Open the session cache a prefill will ingest into, adopting a
    /// shared prefix's pooled pages when one is given. Returns the
    /// cache and the adopted (skip) token count.
    fn open_cache(&self, shared: Option<&SharedPrefix>) -> (KvCache, usize) {
        let m = &self.model.info;
        let mut cache = turbo_cache_for(
            m.n_layers,
            m.n_heads,
            m.d_head,
            m.block,
            self.kv_bits,
            self.n_2bit_heads,
            Arc::clone(&self.pages),
        );
        let skip = match shared {
            Some(sp) => {
                adopt_shared_prefix(&mut cache, sp);
                sp.tokens
            }
            None => 0,
        };
        (cache, skip)
    }

    /// Seal a fully-prefilled cache into a serving session — shared by
    /// the one-shot and chunked prefill paths so both build the exact
    /// same state.
    fn seal_session(&self, cache: KvCache) -> TurboCpuSession {
        let m = &self.model.info;
        let slabs = TurboSlabs::new(
            m.n_layers,
            m.n_heads,
            m.max_ctx,
            m.d_head,
            m.block,
        );
        let inner = TurboSession::from_parts_pooled(
            cache,
            slabs,
            Arc::clone(&self.pool),
        );
        TurboCpuSession {
            inner,
            scratches: vec![DecodeScratch::new(); self.pool.threads()],
            model_scratch: ModelScratch::new(),
        }
    }
}

/// Mid-prefill state for the TurboCpu path: the session cache being
/// ingested into plus the model's float-prefix cursor. Dropping it
/// mid-flight (cancel, preemption) releases every pooled page ref
/// through the cache's strict `release` drop path — abandoning a
/// half-done prefill leaks nothing.
pub struct CpuPrefillCursor {
    cache: KvCache,
    model: PrefillCursor,
}

/// TurboCpu per-request state: the same paged cache + slabs + sync
/// cursors as the executable path ([`TurboSession`]), plus the decode
/// scratches the CPU attention fan-out reuses (one per pool thread)
/// and the model-math scratch — zero steady-state allocation.
pub struct TurboCpuSession {
    pub inner: TurboSession,
    scratches: Vec<DecodeScratch>,
    model_scratch: ModelScratch,
}

impl AttentionBackend for TurboCpuBackend {
    type Session = TurboCpuSession;

    fn name(&self) -> &'static str {
        "turbo-cpu"
    }

    fn prefill(
        &self,
        _bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
    ) -> Result<(Vec<f32>, TurboCpuSession, Option<SharedPrefix>)> {
        if let Some(sp) = shared {
            debug_assert!(sp.tokens <= prompt.len());
        }
        let (mut cache, skip) = self.open_cache(shared);
        let logits =
            self.model.prefill_from(prompt, skip, &self.pool, &mut cache)?;
        let reg = collect_prefix(&cache, prompt.len());
        Ok((logits, self.seal_session(cache), reg))
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &self,
        _bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
        cursor: &mut Option<BackendState>,
        max_tokens: usize,
    ) -> Result<PrefillChunkOut<TurboCpuSession>> {
        if cursor.is_none() {
            let (cache, skip) = self.open_cache(shared);
            let model = self.model.begin_prefill(prompt, skip, &cache)?;
            *cursor =
                Some(BackendState::new(CpuPrefillCursor { cache, model }));
        }
        let st = cursor
            .as_mut()
            .expect("cursor installed above")
            .downcast_mut::<CpuPrefillCursor>();
        let done = self.model.prefill_chunk(
            prompt,
            &mut st.model,
            max_tokens,
            &self.pool,
            &mut st.cache,
        )?;
        match done {
            None => {
                Ok(PrefillChunkOut::Pending { processed: st.model.done() })
            }
            Some(logits) => {
                let st = cursor
                    .take()
                    .expect("cursor present")
                    .downcast::<CpuPrefillCursor>();
                let reg = collect_prefix(&st.cache, prompt.len());
                let session = self.seal_session(st.cache);
                let v = self.model.info.vocab;
                let last_logits = logits[logits.len() - v..].to_vec();
                Ok(PrefillChunkOut::Done { last_logits, session, reg })
            }
        }
    }

    fn decode_step(
        &self,
        _bundle: &mut ModelBundle,
        session: &mut TurboCpuSession,
        token: u8,
        pos: usize,
        sparse_topk_pages: usize,
    ) -> Result<DecodeOut> {
        let nk =
            session.inner.sync_slabs_sparse(sparse_topk_pages > 0)?;
        self.model.decode_step(
            &session.inner.slabs,
            nk,
            token,
            pos,
            &self.pool,
            &mut session.scratches,
            &mut session.model_scratch,
            sparse_topk_pages,
        )
    }

    fn fold_new_token(
        &self,
        _bundle: &ModelBundle,
        session: &mut TurboCpuSession,
        k_new: &[f32],
        v_new: &[f32],
        _pos: usize,
    ) {
        fold_kv_into_cache(&mut session.inner.cache, k_new, v_new);
    }

    fn cache_stats(&self, session: &TurboCpuSession) -> Option<CacheStats> {
        let mut stats = session.inner.cache.stats();
        stats.slab_bytes = session.inner.slabs.bytes();
        Some(stats)
    }

    fn page_pool(&self) -> Option<&SharedPagePool> {
        Some(&self.pages)
    }
}

// ---------------------------------------------------------------------------
// Flash path
// ---------------------------------------------------------------------------

/// Exact FlashAttention baseline over persistent FP32 slabs.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlashBackend;

/// Flash per-request state: the float K/V slabs.
pub struct FlashSession {
    pub slabs: FlashSlabs,
}

impl AttentionBackend for FlashBackend {
    type Session = FlashSession;

    fn name(&self) -> &'static str {
        "flash"
    }

    fn prefill(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        _shared: Option<&SharedPrefix>,
    ) -> Result<(Vec<f32>, FlashSession, Option<SharedPrefix>)> {
        // No page pool on the float baseline: nothing to fork from or
        // register.
        let out = bundle.prefill(prompt, false)?;
        let (kf, vf) = out.flash_cache.expect("flash prefill returns cache");
        Ok((out.logits, FlashSession { slabs: FlashSlabs { kf, vf } }, None))
    }

    fn decode_step(
        &self,
        bundle: &mut ModelBundle,
        session: &mut FlashSession,
        token: u8,
        pos: usize,
        _sparse_topk_pages: usize,
    ) -> Result<DecodeOut> {
        // The exact float baseline has no pages to skip: always dense.
        // The cache holds exactly the `pos` tokens before this one.
        bundle.decode_flash(&mut session.slabs, token, pos, pos)
    }

    fn fold_new_token(
        &self,
        bundle: &ModelBundle,
        session: &mut FlashSession,
        k_new: &[f32],
        v_new: &[f32],
        pos: usize,
    ) {
        let (l_n, h_n) = (bundle.n_layers(), bundle.n_heads());
        let (c, dh) = (bundle.max_ctx(), bundle.d_head());
        for l in 0..l_n {
            for h in 0..h_n {
                let src = (l * h_n + h) * dh;
                let dst = ((l * h_n + h) * c + pos) * dh;
                session.slabs.kf[dst..dst + dh]
                    .copy_from_slice(&k_new[src..src + dh]);
                session.slabs.vf[dst..dst + dh]
                    .copy_from_slice(&v_new[src..src + dh]);
            }
        }
    }

    fn cache_stats(&self, _session: &FlashSession) -> Option<CacheStats> {
        // Uncompressed float cache: nothing to report against the
        // compression metrics.
        None
    }
}

// ---------------------------------------------------------------------------
// Runtime dispatch (object-safe erasure)
// ---------------------------------------------------------------------------

/// Type-erased per-session backend state, stored by the engine.
pub struct BackendState(Box<dyn Any>);

impl BackendState {
    pub fn new<S: Any>(state: S) -> BackendState {
        BackendState(Box::new(state))
    }

    /// Borrow as a concrete session type. Panics on backend/session
    /// mismatch — states never migrate between backends inside one
    /// engine, so a mismatch is a bug, not a runtime condition.
    pub fn downcast_ref<S: Any>(&self) -> &S {
        self.0
            .downcast_ref::<S>()
            .expect("session state does not match backend")
    }

    pub fn downcast_mut<S: Any>(&mut self) -> &mut S {
        self.0
            .downcast_mut::<S>()
            .expect("session state does not match backend")
    }

    /// Take back the concrete state by value — how a backend consumes
    /// its own prefill cursor on the final chunk. Panics on mismatch,
    /// same contract as [`BackendState::downcast_ref`].
    pub fn downcast<S: Any>(self) -> S {
        *self
            .0
            .downcast::<S>()
            .unwrap_or_else(|_| panic!("session state does not match backend"))
    }
}

/// Object-safe facade over [`AttentionBackend`], so the engine can pick
/// a path at runtime without being generic over it.
pub trait DynBackend {
    fn name(&self) -> &'static str;
    fn prefill(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
    ) -> Result<(Vec<f32>, BackendState, Option<SharedPrefix>)>;
    /// See [`AttentionBackend::decode_step`] (including the
    /// `sparse_topk_pages` contract).
    fn decode_step(
        &self,
        bundle: &mut ModelBundle,
        state: &mut BackendState,
        token: u8,
        pos: usize,
        sparse_topk_pages: usize,
    ) -> Result<DecodeOut>;
    fn fold_new_token(
        &self,
        bundle: &ModelBundle,
        state: &mut BackendState,
        k_new: &[f32],
        v_new: &[f32],
        pos: usize,
    );
    fn cache_stats(&self, state: &BackendState) -> Option<CacheStats>;
    /// See [`AttentionBackend::page_pool`].
    fn page_pool(&self) -> Option<&SharedPagePool>;
    /// See [`AttentionBackend::supports_chunked_prefill`].
    fn supports_chunked_prefill(&self) -> bool;
    /// See [`AttentionBackend::prefill_chunk`]; the completed session is
    /// type-erased like [`DynBackend::prefill`]'s.
    fn prefill_chunk(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
        cursor: &mut Option<BackendState>,
        max_tokens: usize,
    ) -> Result<PrefillChunkOut<BackendState>>;
}

struct Erased<B>(B);

impl<B> DynBackend for Erased<B>
where
    B: AttentionBackend,
    B::Session: Any,
{
    fn name(&self) -> &'static str {
        self.0.name()
    }

    fn prefill(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
    ) -> Result<(Vec<f32>, BackendState, Option<SharedPrefix>)> {
        let (logits, session, reg) = self.0.prefill(bundle, prompt, shared)?;
        Ok((logits, BackendState::new(session), reg))
    }

    fn decode_step(
        &self,
        bundle: &mut ModelBundle,
        state: &mut BackendState,
        token: u8,
        pos: usize,
        sparse_topk_pages: usize,
    ) -> Result<DecodeOut> {
        self.0.decode_step(
            bundle,
            state.downcast_mut(),
            token,
            pos,
            sparse_topk_pages,
        )
    }

    fn fold_new_token(
        &self,
        bundle: &ModelBundle,
        state: &mut BackendState,
        k_new: &[f32],
        v_new: &[f32],
        pos: usize,
    ) {
        self.0
            .fold_new_token(bundle, state.downcast_mut(), k_new, v_new, pos)
    }

    fn cache_stats(&self, state: &BackendState) -> Option<CacheStats> {
        self.0.cache_stats(state.downcast_ref())
    }

    fn page_pool(&self) -> Option<&SharedPagePool> {
        self.0.page_pool()
    }

    fn supports_chunked_prefill(&self) -> bool {
        self.0.supports_chunked_prefill()
    }

    fn prefill_chunk(
        &self,
        bundle: &mut ModelBundle,
        prompt: &[u8],
        shared: Option<&SharedPrefix>,
        cursor: &mut Option<BackendState>,
        max_tokens: usize,
    ) -> Result<PrefillChunkOut<BackendState>> {
        let out =
            self.0.prefill_chunk(bundle, prompt, shared, cursor, max_tokens)?;
        Ok(match out {
            PrefillChunkOut::Pending { processed } => {
                PrefillChunkOut::Pending { processed }
            }
            PrefillChunkOut::Done { last_logits, session, reg } => {
                PrefillChunkOut::Done {
                    last_logits,
                    session: BackendState::new(session),
                    reg,
                }
            }
        })
    }
}

/// Construct the backend for an engine configuration — the single place
/// a `PathMode` is matched on. `pool` is the decode worker pool every
/// session of this backend forks its per-(layer, head) work onto
/// (`EngineConfig.decode_threads` sizes it; 1 thread = the exact serial
/// path). `model` is the serving geometry (the engine passes its
/// bundle's manifest) and `seed` feeds the deterministic [`CpuModel`] —
/// both used only by [`PathMode::TurboCpu`]; the flash baseline ignores
/// everything but the mode.
pub fn backend_for(
    mode: PathMode,
    kv_bits: Bits,
    n_2bit_heads: usize,
    seed: u64,
    model: &ModelInfo,
    pool: Arc<WorkerPool>,
) -> Box<dyn DynBackend> {
    match mode {
        PathMode::Turbo => {
            Box::new(Erased(TurboBackend::new(kv_bits, n_2bit_heads, pool)))
        }
        PathMode::TurboCpu => Box::new(Erased(TurboCpuBackend::new(
            model,
            seed,
            kv_bits,
            n_2bit_heads,
            pool,
        ))),
        PathMode::Flash => Box::new(Erased(FlashBackend)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{prop, Rng};

    const L: usize = 2;
    const H: usize = 2;
    const DH: usize = 8;
    const BLOCK: usize = 4;
    const CTX: usize = 32;

    fn session() -> TurboSession {
        session_with_threads(1)
    }

    fn session_with_threads(threads: usize) -> TurboSession {
        let pm = PrecisionMap::uniform(L, H, Bits::Int4);
        let cache = KvCache::new(KvCacheConfig::new(L, H, DH, BLOCK, pm));
        TurboSession::from_parts_pooled(
            cache,
            TurboSlabs::new(L, H, CTX, DH, BLOCK),
            Arc::new(WorkerPool::new(threads)),
        )
    }

    fn push_all(s: &mut TurboSession, rng: &mut Rng) {
        for l in 0..L {
            for h in 0..H {
                let k = rng.normal_vec(DH, 1.0);
                let v = rng.normal_vec(DH, 1.0);
                s.cache.k_stream_mut(l, h).push_token(&k);
                s.cache.v_stream_mut(l, h).push_token(&v);
            }
        }
    }

    fn ingest_all(s: &mut TurboSession, rng: &mut Rng, tokens: usize) {
        use crate::quant::quant_sym_int8;
        for l in 0..L {
            for h in 0..H {
                let k = quant_sym_int8(&rng.normal_vec(tokens * DH, 1.0));
                s.cache.k_stream_mut(l, h).ingest_q1_block(
                    &k.codes, k.scale, tokens,
                );
                let v = quant_sym_int8(&rng.normal_vec(tokens * DH, 1.0));
                s.cache.v_stream_mut(l, h).ingest_q1_block(
                    &v.codes, v.scale, tokens,
                );
            }
        }
    }

    /// Backend-parity oracle for the slabs: however sparsely `sync_slabs`
    /// was called along the way — and whatever the worker-pool width —
    /// the slab contents must equal a fresh full rematerialization of
    /// every stream.
    #[test]
    fn incremental_slab_sync_equals_full_rematerialization() {
        prop::run("slab sync == remat", 25, |g| {
            let threads = *g.choose(&[1usize, 2, 4, 7]);
            let mut s = session_with_threads(threads);
            let mut rng = Rng::new(g.seed());
            let prefill = g.usize_in(0, 12);
            if prefill > 0 {
                ingest_all(&mut s, &mut rng, prefill);
            }
            let steps = g.usize_in(1, CTX - 1 - prefill);
            let sync_every = g.usize_in(1, 4);
            for i in 0..steps {
                push_all(&mut s, &mut rng);
                if i % sync_every == 0 {
                    s.sync_slabs().expect("sync");
                }
            }
            let nk = s.sync_slabs().expect("sync");
            assert_eq!(nk, prefill + steps);
            let nb = CTX / BLOCK;
            let nbv = nk.div_ceil(BLOCK);
            let mut scratch = Vec::new();
            let mut q1 = vec![0i8; CTX * DH];
            let mut sc = vec![0.0f32; nb];
            for l in 0..L {
                for h in 0..H {
                    let base = (l * H + h) * CTX * DH;
                    let sbase = (l * H + h) * nb;
                    let hc = s.cache.head(l, h);
                    let got = hc.k.read_q1_into(&mut scratch, &mut q1, &mut sc);
                    assert_eq!(got, nk);
                    assert_eq!(
                        &s.slabs.k8[base..base + nk * DH],
                        &q1[..nk * DH],
                        "K codes (l={l} h={h})"
                    );
                    assert_eq!(
                        &s.slabs.sk[sbase..sbase + nbv],
                        &sc[..nbv],
                        "K scales (l={l} h={h})"
                    );
                    let got = hc.v.read_q1_into(&mut scratch, &mut q1, &mut sc);
                    assert_eq!(got, nk);
                    assert_eq!(
                        &s.slabs.v8[base..base + nk * DH],
                        &q1[..nk * DH],
                        "V codes (l={l} h={h})"
                    );
                    assert_eq!(
                        &s.slabs.sv[sbase..sbase + nbv],
                        &sc[..nbv],
                        "V scales (l={l} h={h})"
                    );
                }
            }
        });
    }

    #[test]
    fn sync_is_incremental_after_warmup() {
        let mut s = session();
        let mut rng = Rng::new(5);
        for _ in 0..(BLOCK * 2 + 1) {
            push_all(&mut s, &mut rng);
        }
        assert_eq!(s.sync_slabs().unwrap(), BLOCK * 2 + 1);
        assert_eq!(s.synced_pages, 2);
        assert_eq!(s.synced_buf, 1);
        // No mutation: cursors stable, nk unchanged.
        assert_eq!(s.sync_slabs().unwrap(), BLOCK * 2 + 1);
        assert_eq!(s.synced_pages, 2);
        push_all(&mut s, &mut rng);
        assert_eq!(s.sync_slabs().unwrap(), BLOCK * 2 + 2);
        assert_eq!(s.synced_buf, 2);
    }

    #[test]
    fn backend_for_dispatches_by_mode() {
        let info = crate::runtime::Manifest::cpu_substrate().model;
        let pool = Arc::new(WorkerPool::new(2));
        let t = backend_for(
            PathMode::Turbo,
            Bits::Int4,
            0,
            0,
            &info,
            Arc::clone(&pool),
        );
        let c = backend_for(
            PathMode::TurboCpu,
            Bits::Int4,
            0,
            0,
            &info,
            Arc::clone(&pool),
        );
        let f = backend_for(PathMode::Flash, Bits::Int4, 0, 0, &info, pool);
        assert_eq!(t.name(), "turbo");
        assert_eq!(c.name(), "turbo-cpu");
        assert_eq!(f.name(), "flash");
    }

    /// The third backend's headline property: a full prefill + decode +
    /// fold loop through the `DynBackend` interface with **no artifacts
    /// anywhere** — attention on the integer kernels, cache/slab state
    /// identical in shape to the executable path.
    #[test]
    fn turbo_cpu_backend_serves_without_artifacts() {
        let info = crate::runtime::Manifest::cpu_substrate().model;
        let pool = Arc::new(WorkerPool::new(2));
        let backend =
            backend_for(PathMode::TurboCpu, Bits::Int4, 0, 1, &info, pool);
        let mut bundle = ModelBundle::new(
            crate::runtime::Runtime::cpu_substrate(),
        );
        let prompt = b"turbo cpu serves ".to_vec();
        let (logits, mut state, _reg) =
            backend.prefill(&mut bundle, &prompt, None).expect("prefill");
        assert_eq!(logits.len(), prompt.len() * info.vocab);
        let mut pos = prompt.len();
        let mut token = 42u8;
        for _ in 0..6 {
            let out = backend
                .decode_step(&mut bundle, &mut state, token, pos, 0)
                .expect("decode");
            assert_eq!(out.logits.len(), info.vocab);
            backend
                .fold_new_token(&bundle, &mut state, &out.k_new, &out.v_new, pos);
            token = crate::model::argmax(&out.logits) as u8;
            pos += 1;
        }
        let stats = backend.cache_stats(&state).expect("turbo-family stats");
        assert_eq!(stats.tokens, prompt.len() + 6);
        assert!(stats.slab_bytes > 0, "slab working set reported");
        assert!(
            stats.slab_bytes > stats.bytes,
            "slabs ({}) should dominate the compressed cache ({})",
            stats.slab_bytes,
            stats.bytes
        );
    }

    #[test]
    fn turbo_backend_stats_include_slab_working_set() {
        let s = session();
        let backend =
            TurboBackend::new(Bits::Int4, 0, Arc::new(WorkerPool::new(1)));
        let stats = backend.cache_stats(&s).expect("stats");
        assert_eq!(stats.slab_bytes, s.slabs.bytes());
        assert!(stats.slab_bytes > 0);
    }

    #[test]
    #[should_panic(expected = "does not match")]
    fn state_downcast_mismatch_panics() {
        let state = BackendState::new(42usize);
        let _: &FlashSession = state.downcast_ref();
    }

    /// Prefix sharing through the `DynBackend` interface: a session
    /// forked from a registered prefix decodes **bit-identically** to a
    /// fully private session, while its cache stats show the prefix as
    /// shared storage.
    #[test]
    fn forked_session_decodes_bit_identical_to_private() {
        let info = crate::runtime::Manifest::cpu_substrate().model;
        let pool = Arc::new(WorkerPool::new(2));
        let backend =
            backend_for(PathMode::TurboCpu, Bits::Int4, 1, 7, &info, pool);
        let mut bundle = ModelBundle::new(
            crate::runtime::Runtime::cpu_substrate(),
        );
        // Prompt crossing one page boundary (block = 32): 40 tokens.
        let prompt: Vec<u8> =
            (0..40).map(|i| b'a' + (i % 17) as u8).collect();

        // Donor session registers its prefix.
        let (_, _donor, reg) =
            backend.prefill(&mut bundle, &prompt, None).expect("donor");
        let reg = reg.expect("page-crossing prompt registers a prefix");
        assert_eq!(reg.tokens, 32);
        assert_eq!(reg.n_pages, 1);
        assert_eq!(reg.n_streams, info.n_layers * info.n_heads);

        // Forked vs private session, same decode trace.
        let decode = |state: &mut BackendState,
                      bundle: &mut ModelBundle|
         -> Vec<u32> {
            let mut bits = Vec::new();
            let mut token = 42u8;
            let mut pos = prompt.len();
            for _ in 0..8 {
                let out = backend
                    .decode_step(bundle, state, token, pos, 0)
                    .expect("decode");
                backend.fold_new_token(
                    bundle, state, &out.k_new, &out.v_new, pos,
                );
                bits.extend(out.logits.iter().map(|x| x.to_bits()));
                token = crate::model::argmax(&out.logits) as u8;
                pos += 1;
            }
            bits
        };
        let (fl, mut forked, _) = backend
            .prefill(&mut bundle, &prompt, Some(&reg))
            .expect("forked");
        let (pl, mut private, _) =
            backend.prefill(&mut bundle, &prompt, None).expect("private");
        let bits =
            |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&fl), bits(&pl), "prefill logits bitwise");
        let fbits = decode(&mut forked, &mut bundle);
        let pbits = decode(&mut private, &mut bundle);
        assert_eq!(fbits, pbits, "decode logits bitwise");

        // Accounting: the forked session shares its prefix pages, the
        // private one owns everything (refs taken by donor+fork make
        // even the donor's copy "shared", but private's *tail* pages and
        // its own stats stay meaningful).
        let fstats = backend.cache_stats(&forked).expect("stats");
        assert!(fstats.shared_page_bytes > 0, "prefix shared");
        let pool_stats = backend
            .page_pool()
            .expect("turbo-family pool")
            .read()
            .expect("pool")
            .stats();
        assert!(pool_stats.shared_bytes > 0);
        assert!(pool_stats.dedup_ratio() > 0.0);
    }

    /// Sub-page prompts register nothing and fork from nothing.
    #[test]
    fn short_prompt_registers_no_prefix() {
        let info = crate::runtime::Manifest::cpu_substrate().model;
        let pool = Arc::new(WorkerPool::new(1));
        let backend =
            backend_for(PathMode::TurboCpu, Bits::Int4, 0, 3, &info, pool);
        let mut bundle = ModelBundle::new(
            crate::runtime::Runtime::cpu_substrate(),
        );
        let (_, _s, reg) = backend
            .prefill(&mut bundle, b"short", None)
            .expect("prefill");
        assert!(reg.is_none(), "5 tokens < one 32-token page");
    }
}
