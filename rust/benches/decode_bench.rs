//! Decode-step cost vs. context length, worker-thread count, and
//! kernelization.
//!
//! Three questions, all on the pure-Rust substrate (no artifacts needed):
//!
//! 1. **Asymptotics** — the incremental `Q1View` + persistent slabs vs
//!    the seed path's per-token full-cache rematerialization:
//!
//!    * `cache-sync(view)`  — fold one token + incremental slab sync
//!      (`TurboSession::sync_slabs`). Should be **near-flat** in
//!      context: pages are dequantized once when created, so
//!      steady-state work is O(new tokens).
//!    * `cache-remat(seed)` — fold one token + fresh `read_q1_into` of
//!      every stream (what `ModelBundle::decode_turbo` did per token).
//!      Linear in context.
//!
//! 2. **Parallel decode** — the per-(layer, head) fan-out over the
//!    hand-rolled worker pool (`decode_threads`):
//!
//!    * `decode-step turbo tN` — fold + pooled slab sync + pooled
//!      per-stream INT8 attention (`turbo_decode_streams`, one
//!      `DecodeScratch` per worker), for N in {1, 2, 4, 8}. `t1` is the
//!      exact serial path; outputs are bit-identical across N (the
//!      parallel-parity suite proves it), so the sweep measures pure
//!      scheduling win.
//!    * `decode-step flash` — fold (one memcpy per stream) + exact
//!      float attention, the baseline backend's step shape.
//!
//! 3. **Kernels vs scalar** — the integer micro-kernels
//!    (`qk_dot_block`/`ipv_acc`/`Sas::exp_block` inside
//!    `turbo_decode_into`) against the seed scalar loop
//!    (`turbo_decode_into_scalar`), at every (ctx, threads) point:
//!    `attn turbo tN` / `attn turbo-scalar tN` time **only** the
//!    stream fan-out over a pre-synced frozen cache (no fold, sync, or
//!    RNG in the timed body), so the speedup isolates the
//!    kernelization.
//!
//! 4. **Shared-prefix batched decode** — B ∈ {2, 4, 8} sessions forked
//!    from one 512-token page-aligned common prefix on the refcounted
//!    page pool: per-token decode latency plus the pool's measured
//!    dedup ratio (exactly (B-1)/B with only the prefix resident).
//!
//! 5. **Integer microkernels, dispatched vs scalar arm** — `qk
//!    micro` / `ipv micro` / `sas micro` time `qk_dot_block`,
//!    `ipv_acc` and `Sas::exp_block` directly (one ctx-row block, no
//!    attention bookkeeping) against the pinned scalar arm, so the
//!    recorded speedup isolates the SIMD dispatch itself
//!    (AVX2/NEON vs the autovectorized scalar loops).
//!
//! 6. **Capped vs uncapped serving** — the same 3-request batch
//!    through the full TurboCpu engine with `pool_byte_cap` below two
//!    flushed sessions vs unbounded. Output is bit-identical by
//!    construction (the purity invariant); the measured ratio prices
//!    what the bounded memory costs in preemption + replay recompute.
//!
//! 7. **Top-k page-sparse decode** — `sparse-topk k=K ctx=C` times the
//!    sparse stream fan-out (`turbo_decode_streams_sparse`: envelope
//!    scoring, top-k page selection, mean-value fold of skipped pages)
//!    against the dense fan-out at ctx 1024 and 4096, and reports the
//!    fraction of KV code bytes actually read (`bytes_read_ratio`,
//!    from the step's own skip counters).
//!
//! `--json` additionally writes every case plus the computed speedups and
//! the shared-prefix scenario to `BENCH_decode.json` (the perf-trajectory
//! artifact). The payload records `kernel_backend` — the ISA the
//! dispatched cases actually ran — and `--kernel-backend` /
//! `TURBO_KERNEL` pin it (`scalar` makes every dispatched-vs-scalar
//! speedup ~1.0 by construction).

use std::sync::Arc;

use turboattention::attention::backend::TurboSession;
use turboattention::attention::{
    turbo_decode_streams, turbo_decode_streams_scalar,
    turbo_decode_streams_sparse, DecodeScratch,
};
use turboattention::bench::Bencher;
use turboattention::coordinator::{
    Engine, EngineConfig, GenRequest, PathMode, TokenEvent,
};
use turboattention::kernels;
use turboattention::kvcache::{KvCache, KvCacheConfig, PagePool, PrecisionMap};
use turboattention::model::{ModelBundle, TurboSlabs};
use turboattention::runtime::Runtime;
use turboattention::sas::Sas;
use turboattention::pool::WorkerPool;
use turboattention::quant::{quant_sym_int8, Bits};
use turboattention::testutil::Rng;
use turboattention::util::cli::Args;

const L: usize = 2;
const H: usize = 4;
const DH: usize = 64;
const BLOCK: usize = 32;
/// Headroom tokens so a bench case can fold one token per iteration
/// (warmup + measured) without outgrowing the slabs.
const SLACK: usize = 2048;

fn new_session(ctx: usize, rng: &mut Rng, threads: usize) -> TurboSession {
    let max_ctx = ctx + SLACK;
    let pm = PrecisionMap::uniform(L, H, Bits::Int4);
    let cache = KvCache::new(KvCacheConfig::new(L, H, DH, BLOCK, pm));
    let mut sess = TurboSession::from_parts_pooled(
        cache,
        TurboSlabs::new(L, H, max_ctx, DH, BLOCK),
        Arc::new(WorkerPool::new(threads)),
    );
    for _ in 0..ctx {
        fold_token(&mut sess, rng);
    }
    sess.sync_slabs().expect("sync");
    sess
}

fn fold_token(sess: &mut TurboSession, rng: &mut Rng) {
    for l in 0..L {
        for h in 0..H {
            let k = rng.normal_vec(DH, 1.0);
            let v = rng.normal_vec(DH, 1.0);
            sess.cache.k_stream_mut(l, h).push_token(&k);
            sess.cache.v_stream_mut(l, h).push_token(&v);
        }
    }
}

/// The seed path's per-token cache read: rematerialize every stream into
/// the slabs from scratch.
fn remat_all(sess: &mut TurboSession, scratch: &mut Vec<u8>) -> usize {
    let max_ctx = sess.slabs.k8.len() / (L * H * DH);
    let nb = max_ctx / BLOCK;
    let mut nk = 0;
    for l in 0..L {
        for h in 0..H {
            let base = (l * H + h) * max_ctx * DH;
            let sbase = (l * H + h) * nb;
            let hc = sess.cache.head(l, h);
            nk = hc.k.read_q1_into(
                scratch,
                &mut sess.slabs.k8[base..base + max_ctx * DH],
                &mut sess.slabs.sk[sbase..sbase + nb],
            );
            hc.v.read_q1_into(
                scratch,
                &mut sess.slabs.v8[base..base + max_ctx * DH],
                &mut sess.slabs.sv[sbase..sbase + nb],
            );
        }
    }
    nk
}

/// Exact single-query attention over a float cache (flash decode shape).
fn flash_attend(q: &[f32], kf: &[f32], vf: &[f32], nk: usize, out: &mut [f32]) {
    let d = q.len();
    let scale = 1.0 / (d as f32).sqrt();
    let mut m = f32::NEG_INFINITY;
    let mut l_sum = 0.0f32;
    out.fill(0.0);
    for t in 0..nk {
        let k_row = &kf[t * d..(t + 1) * d];
        let s: f32 =
            q.iter().zip(k_row).map(|(a, b)| a * b).sum::<f32>() * scale;
        let m_new = m.max(s);
        let alpha = if m == f32::NEG_INFINITY { 0.0 } else { (m - m_new).exp() };
        let p = (s - m_new).exp();
        let v_row = &vf[t * d..(t + 1) * d];
        for (o, &vv) in out.iter_mut().zip(v_row) {
            *o = *o * alpha + p * vv;
        }
        l_sum = l_sum * alpha + p;
        m = m_new;
    }
    let inv = 1.0 / l_sum.max(1e-20);
    out.iter_mut().for_each(|o| *o *= inv);
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let emit_json = args.flag("json");
    if let Some(kb) = args.opt("kernel-backend") {
        kernels::force_kernel_backend(kb).expect("--kernel-backend");
    }
    let backend = kernels::kernel_backend().name();
    println!(
        "== bench: decode step vs context, threads, and kernelization \
         (Q1View slabs + worker pool) ==\n"
    );
    println!("kernel backend: {backend}\n");
    // Cap iterations so a case's token folds stay within SLACK.
    let mut b = Bencher::with_limits(
        std::time::Duration::from_millis(50),
        std::time::Duration::from_millis(500),
        800,
    );
    let contexts = [256usize, 512, 1024];
    let thread_sweep = [1usize, 2, 4, 8];

    for &ctx in &contexts {
        let mut rng = Rng::new(42);
        let mut sess = new_session(ctx, &mut rng, 1);
        b.bench(&format!("cache-sync(view) ctx={ctx}"), || {
            fold_token(&mut sess, &mut rng);
            sess.sync_slabs().expect("sync")
        });

        let mut sess = new_session(ctx, &mut rng, 1);
        let mut scratch8 = Vec::new();
        b.bench(&format!("cache-remat(seed) ctx={ctx}"), || {
            fold_token(&mut sess, &mut rng);
            remat_all(&mut sess, &mut scratch8)
        });

        // Thread sweep: the full decode step (fold + pooled sync +
        // pooled per-stream attention) at each pool width.
        for &threads in &thread_sweep {
            let mut sess = new_session(ctx, &mut rng, threads);
            let pool = Arc::clone(sess.pool());
            let mut scratches = vec![DecodeScratch::new(); threads];
            let mut ml = vec![(0.0f32, 0.0f32); L * H];
            let mut out = vec![0.0f32; L * H * DH];
            let max_ctx = ctx + SLACK;
            let nb = max_ctx / BLOCK;
            // Fixed query per case: q values don't affect attention
            // cost, and generating L*H*DH normals per iteration would
            // add a serial term that dilutes the measured fan-out.
            let q = rng.normal_vec(L * H * DH, 1.0);
            b.bench(&format!("decode-step turbo t{threads} ctx={ctx}"), || {
                fold_token(&mut sess, &mut rng);
                let nk = sess.sync_slabs().expect("sync");
                debug_assert_eq!(sess.slabs.sk.len(), L * H * nb);
                turbo_decode_streams(
                    &pool,
                    &q,
                    &sess.slabs.k8,
                    &sess.slabs.v8,
                    &sess.slabs.sk,
                    &sess.slabs.sv,
                    DH,
                    nk,
                    BLOCK,
                    -6.0,
                    &mut scratches,
                    &mut ml,
                    &mut out,
                )
                .expect("decode");
                out[0]
            });
        }

        // Kernel vs scalar, attention only: a pre-synced frozen session
        // (no fold, no sync, no RNG in the timed body), so the recorded
        // speedup isolates the kernelization of `turbo_decode_into`.
        for &threads in &thread_sweep {
            let mut sess = new_session(ctx, &mut rng, threads);
            let pool = Arc::clone(sess.pool());
            let nk = sess.sync_slabs().expect("sync");
            let mut scratches = vec![DecodeScratch::new(); threads];
            let mut ml = vec![(0.0f32, 0.0f32); L * H];
            let mut out = vec![0.0f32; L * H * DH];
            let q = rng.normal_vec(L * H * DH, 1.0);
            for scalar in [false, true] {
                let run = if scalar {
                    turbo_decode_streams_scalar
                } else {
                    turbo_decode_streams
                };
                let variant = if scalar { "turbo-scalar" } else { "turbo" };
                b.bench(&format!("attn {variant} t{threads} ctx={ctx}"), || {
                    run(
                        &pool,
                        &q,
                        &sess.slabs.k8,
                        &sess.slabs.v8,
                        &sess.slabs.sk,
                        &sess.slabs.sv,
                        DH,
                        nk,
                        BLOCK,
                        -6.0,
                        &mut scratches,
                        &mut ml,
                        &mut out,
                    )
                    .expect("decode");
                    out[0]
                });
            }
        }

        let max_ctx = ctx + SLACK;
        let mut kf = vec![0.0f32; L * H * max_ctx * DH];
        let mut vf = vec![0.0f32; L * H * max_ctx * DH];
        let mut nk = ctx;
        for t in 0..ctx {
            for s in 0..L * H {
                let base = (s * max_ctx + t) * DH;
                kf[base..base + DH].copy_from_slice(&rng.normal_vec(DH, 1.0));
                vf[base..base + DH].copy_from_slice(&rng.normal_vec(DH, 1.0));
            }
        }
        let mut out = vec![0.0f32; DH];
        b.bench(&format!("decode-step flash ctx={ctx}"), || {
            for s in 0..L * H {
                let base = (s * max_ctx + nk) * DH;
                kf[base..base + DH].copy_from_slice(&rng.normal_vec(DH, 1.0));
                vf[base..base + DH].copy_from_slice(&rng.normal_vec(DH, 1.0));
            }
            nk += 1;
            let q = rng.normal_vec(DH, 1.0);
            let mut acc = 0.0f32;
            for s in 0..L * H {
                let base = s * max_ctx * DH;
                flash_attend(
                    &q,
                    &kf[base..base + max_ctx * DH],
                    &vf[base..base + max_ctx * DH],
                    nk,
                    &mut out,
                );
                acc += out[0];
            }
            acc
        });
        println!();
    }

    // Top-k page-sparse decode: frozen pre-synced sessions (page
    // summaries synced alongside the codes), attention only, so the
    // sweep isolates what envelope scoring + skipping buys over the
    // dense fan-out at the same context. `bytes_read_ratio` is the KV
    // code bytes the sparse step actually touches relative to dense,
    // computed from the step's own attended/skipped counters.
    let mut sparse_json = Vec::new();
    println!("top-k page-sparse decode (attention only, t4):");
    for &ctx in &[1024usize, 4096] {
        let mut rng = Rng::new(23);
        let mut sess = new_session(ctx, &mut rng, 4);
        let pool = Arc::clone(sess.pool());
        let nk = sess.sync_slabs_sparse(true).expect("sync");
        let n_pages = nk / BLOCK;
        let mut scratches = vec![DecodeScratch::new(); 4];
        let mut ml = vec![(0.0f32, 0.0f32); L * H];
        let mut out = vec![0.0f32; L * H * DH];
        let q = rng.normal_vec(L * H * DH, 1.0);
        let dense_s = b
            .bench(&format!("sparse-dense baseline ctx={ctx}"), || {
                turbo_decode_streams(
                    &pool,
                    &q,
                    &sess.slabs.k8,
                    &sess.slabs.v8,
                    &sess.slabs.sk,
                    &sess.slabs.sv,
                    DH,
                    nk,
                    BLOCK,
                    -6.0,
                    &mut scratches,
                    &mut ml,
                    &mut out,
                )
                .expect("decode");
                out[0]
            })
            .mean_s();
        // Dense reads every K and V code of every stream each step.
        let dense_bytes = (L * H * 2 * nk * DH) as f64;
        println!(
            "  ctx={ctx}: {n_pages} pages/stream, dense {:.3}ms/token",
            dense_s * 1e3
        );
        for &topk in &[4usize, 16, 64] {
            if topk >= n_pages {
                continue;
            }
            let mut skipped = 0u64;
            let mean_s = b
                .bench(&format!("sparse-topk k={topk} ctx={ctx}"), || {
                    let (_, skip) = turbo_decode_streams_sparse(
                        &pool,
                        &q,
                        &sess.slabs.k8,
                        &sess.slabs.v8,
                        &sess.slabs.sk,
                        &sess.slabs.sv,
                        &sess.slabs.kmin,
                        &sess.slabs.kmax,
                        &sess.slabs.vmean,
                        DH,
                        nk,
                        BLOCK,
                        -6.0,
                        topk,
                        &mut scratches,
                        &mut ml,
                        &mut out,
                    )
                    .expect("sparse decode");
                    skipped = skip;
                    out[0]
                })
                .mean_s();
            let bytes_ratio =
                1.0 - (skipped as f64 * 2.0 * (BLOCK * DH) as f64) / dense_bytes;
            println!(
                "    k={topk}: {:.3}ms/token ({:.2}x vs dense), \
                 bytes read {:.3}x",
                mean_s * 1e3,
                dense_s / mean_s.max(1e-12),
                bytes_ratio
            );
            sparse_json.push(format!(
                "{{\"ctx\":{ctx},\"topk\":{topk},\"pages\":{n_pages},\
                 \"per_token_s\":{mean_s:e},\
                 \"dense_per_token_s\":{dense_s:e},\
                 \"pages_skipped_per_step\":{skipped},\
                 \"bytes_read_ratio\":{bytes_ratio:.4}}}"
            ));
        }
    }
    println!();

    // Integer microkernels, dispatched vs pinned scalar arm: one
    // ctx-row key/value block through the raw kernels, no attention
    // bookkeeping, so the speedup is the SIMD dispatch and nothing
    // else. The kernels are branch-free (score values never change the
    // instruction stream), so reusing the buffers across iterations
    // measures the same work every pass.
    println!("integer microkernels ({backend} vs scalar arm):");
    for &ctx in &contexts {
        let mut rng = Rng::new(11);
        let codes = |rng: &mut Rng, n: usize| -> Vec<i8> {
            (0..n).map(|_| (rng.range(0, 255) as i32 - 127) as i8).collect()
        };
        let q8 = codes(&mut rng, DH);
        let k8 = codes(&mut rng, ctx * DH);
        let p8 = codes(&mut rng, ctx);
        let v8 = codes(&mut rng, ctx * DH);
        let mut scores = vec![0i32; ctx];
        let mut acc = vec![0i32; DH];
        b.bench(&format!("qk micro dispatch ctx={ctx}"), || {
            kernels::qk_dot_block(&q8, &k8, DH, &mut scores);
            scores[0]
        });
        b.bench(&format!("qk micro scalar ctx={ctx}"), || {
            kernels::scalar::qk_dot_block(&q8, &k8, DH, &mut scores);
            scores[0]
        });
        b.bench(&format!("ipv micro dispatch ctx={ctx}"), || {
            kernels::ipv_acc(&p8, &v8, DH, &mut acc);
            acc[0]
        });
        b.bench(&format!("ipv micro scalar ctx={ctx}"), || {
            kernels::scalar::ipv_acc(&p8, &v8, DH, &mut acc);
            acc[0]
        });
        let sas = Sas::default();
        let mut row = rng.normal_vec(ctx, 2.0);
        b.bench(&format!("sas micro dispatch ctx={ctx}"), || {
            sas.exp_block(&mut row, 0.5)
        });
        b.bench(&format!("sas micro scalar ctx={ctx}"), || {
            sas.exp_block_scalar(&mut row, 0.5)
        });
    }
    let mut micro_speedups = Vec::new();
    for kind in ["qk", "ipv", "sas"] {
        let mut line = format!("  {kind:<4}");
        for &ctx in &contexts {
            let scalar = format!("{kind} micro scalar ctx={ctx}");
            let disp = format!("{kind} micro dispatch ctx={ctx}");
            match b.speedup(&scalar, &disp) {
                Some(s) => {
                    line.push_str(&format!("  ctx={ctx}: {s:.2}x"));
                    micro_speedups.push(format!(
                        "{{\"kernel\":\"{kind}\",\"ctx\":{ctx},\
                         \"speedup\":{s:.4}}}"
                    ));
                }
                None => line.push_str(&format!("  ctx={ctx}: n/a")),
            }
        }
        println!("{line}");
    }
    println!();

    // Shared-prefix batched decode: B sessions forked from one donor's
    // 512-token page-aligned prefix (all on one refcounted page pool).
    // The timed body is one decode round — every session folds a token,
    // syncs its slabs, and runs the stream attention — so
    // `per_token_s = mean / B`. The dedup ratio is read off the pool:
    // with only the prefix resident it is exactly (B-1)/B.
    let prefix_ctx = 512usize;
    let mut shared_json = Vec::new();
    println!("shared-prefix batched decode ({prefix_ctx}-token common prefix):");
    for &b_sessions in &[2usize, 4, 8] {
        let mut rng = Rng::new(7);
        let pool_pages = PagePool::new_shared();
        let wpool = Arc::new(WorkerPool::new(4));
        let pm = PrecisionMap::uniform(L, H, Bits::Int4);
        let mk_cache = || {
            KvCache::with_pool(
                KvCacheConfig::new(L, H, DH, BLOCK, pm.clone()),
                Arc::clone(&pool_pages),
            )
        };
        // Donor ingests the common prefix once.
        let mut donor = mk_cache();
        for l in 0..L {
            for h in 0..H {
                let k = quant_sym_int8(&rng.normal_vec(prefix_ctx * DH, 1.0));
                donor
                    .k_stream_mut(l, h)
                    .ingest_q1_block(&k.codes, k.scale, prefix_ctx);
                let v = quant_sym_int8(&rng.normal_vec(prefix_ctx * DH, 1.0));
                donor
                    .v_stream_mut(l, h)
                    .ingest_q1_block(&v.codes, v.scale, prefix_ctx);
            }
        }
        let max_ctx = prefix_ctx + SLACK;
        let mut sessions: Vec<TurboSession> = (0..b_sessions)
            .map(|_| {
                let mut cache = mk_cache();
                for l in 0..L {
                    for h in 0..H {
                        let kh = donor.head(l, h).k.pages.clone();
                        cache.k_stream_mut(l, h).adopt_pages(&kh);
                        let vh = donor.head(l, h).v.pages.clone();
                        cache.v_stream_mut(l, h).adopt_pages(&vh);
                    }
                }
                let mut sess = TurboSession::from_parts_pooled(
                    cache,
                    TurboSlabs::new(L, H, max_ctx, DH, BLOCK),
                    Arc::clone(&wpool),
                );
                sess.sync_slabs().expect("sync");
                sess
            })
            .collect();
        // Donor out of the picture: only the B sessions own the prefix,
        // so the pool dedup is exactly (B-1)/B.
        drop(donor);
        let dedup = pool_pages.read().expect("pool").stats().dedup_ratio();
        let mut scratches = vec![DecodeScratch::new(); wpool.threads()];
        let mut ml = vec![(0.0f32, 0.0f32); L * H];
        let mut out = vec![0.0f32; L * H * DH];
        let q = rng.normal_vec(L * H * DH, 1.0);
        let name =
            format!("decode-round shared B={b_sessions} ctx={prefix_ctx}");
        let mean_s = {
            let wpool = &wpool;
            b.bench(&name, || {
                let mut acc = 0.0f32;
                for sess in sessions.iter_mut() {
                    fold_token(sess, &mut rng);
                    let nk = sess.sync_slabs().expect("sync");
                    turbo_decode_streams(
                        wpool,
                        &q,
                        &sess.slabs.k8,
                        &sess.slabs.v8,
                        &sess.slabs.sk,
                        &sess.slabs.sv,
                        DH,
                        nk,
                        BLOCK,
                        -6.0,
                        &mut scratches,
                        &mut ml,
                        &mut out,
                    )
                    .expect("decode");
                    acc += out[0];
                }
                acc
            })
            .mean_s()
        };
        let per_token = mean_s / b_sessions as f64;
        println!(
            "  B={b_sessions}: dedup {dedup:.3}, {:.3}ms/token",
            per_token * 1e3
        );
        shared_json.push(format!(
            "{{\"sessions\":{b_sessions},\"prefix_tokens\":{prefix_ctx},\
             \"dedup_ratio\":{dedup:.4},\"per_token_s\":{per_token:e}}}"
        ));
    }
    println!();

    let flat = |name: &str| {
        let lo = format!("{name} ctx={}", contexts[0]);
        let hi = format!("{name} ctx={}", contexts[contexts.len() - 1]);
        b.speedup(&hi, &lo)
    };
    if let (Some(view), Some(remat)) =
        (flat("cache-sync(view)"), flat("cache-remat(seed)"))
    {
        println!(
            "cache maintenance growth {}x -> {}x context: \
             view {:.2}x (near-flat), remat {:.2}x (linear)",
            contexts[0],
            contexts[contexts.len() - 1],
            view,
            remat
        );
    }
    println!("\nthread-sweep speedup vs t1 (same ctx, kernelized):");
    let mut thread_speedups = Vec::new();
    for &ctx in &contexts {
        let base = format!("decode-step turbo t1 ctx={ctx}");
        let mut line = format!("  ctx={ctx:<5}");
        for &t in &thread_sweep[1..] {
            let name = format!("decode-step turbo t{t} ctx={ctx}");
            match b.speedup(&base, &name) {
                Some(s) => {
                    line.push_str(&format!("  t{t}: {s:.2}x"));
                    thread_speedups.push(format!(
                        "{{\"ctx\":{ctx},\"threads\":{t},\"speedup\":{s:.4}}}"
                    ));
                }
                None => line.push_str(&format!("  t{t}: n/a")),
            }
        }
        println!("{line}");
    }
    println!("\nkernel speedup over scalar (attention only, same ctx/threads):");
    let mut kernel_speedups = Vec::new();
    for &ctx in &contexts {
        let mut line = format!("  ctx={ctx:<5}");
        for &t in &thread_sweep {
            let scalar = format!("attn turbo-scalar t{t} ctx={ctx}");
            let kernel = format!("attn turbo t{t} ctx={ctx}");
            match b.speedup(&scalar, &kernel) {
                Some(s) => {
                    line.push_str(&format!("  t{t}: {s:.2}x"));
                    kernel_speedups.push(format!(
                        "{{\"ctx\":{ctx},\"threads\":{t},\"speedup\":{s:.4}}}"
                    ));
                }
                None => line.push_str(&format!("  t{t}: n/a")),
            }
        }
        println!("{line}");
    }

    // Capped vs uncapped serving: full engine runs on the CPU
    // substrate (its geometry, not this file's L/H/DH constants). One
    // flushed session there is 16 pages x 292B = 4672B, so a 6000B cap
    // admits any single session but forces preemption + replay as soon
    // as a second one flushes — the measured ratio is the wall-clock
    // price of bounded KV memory on an overcommitted batch.
    const POOL_CAP: usize = 6000;
    let serve_batch = |cap: Option<usize>| -> Engine {
        let cfg = EngineConfig {
            mode: PathMode::TurboCpu,
            decode_threads: 2,
            pool_byte_cap: cap,
            ..Default::default()
        };
        let mut e = Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg);
        for (id, prompt) in
            [b"pool aaa".as_slice(), b"pool bbb", b"pool ccc"]
                .iter()
                .enumerate()
        {
            e.submit(GenRequest::new(id as u64, prompt.to_vec(), 64));
        }
        e.run_to_completion().expect("serve batch");
        e
    };
    println!("\ncapped vs uncapped serving (3 requests, TurboCpu engine):");
    let probe = serve_batch(Some(POOL_CAP));
    let (preempts, replayed, evicts) = (
        probe.metrics.preemptions,
        probe.metrics.preempt_replayed_tokens,
        probe.metrics.pool_memo_evictions,
    );
    b.bench("serve-batch uncapped", || {
        serve_batch(None).metrics.tokens_generated
    });
    b.bench("serve-batch capped", || {
        serve_batch(Some(POOL_CAP)).metrics.tokens_generated
    });
    let cap_overhead = b.speedup("serve-batch capped", "serve-batch uncapped");
    match cap_overhead {
        Some(o) => println!(
            "  cap {POOL_CAP}B: {o:.2}x wall overhead | {preempts} \
             preemptions, {replayed} replayed tokens, {evicts} memo \
             evictions per run"
        ),
        None => println!("  cap {POOL_CAP}B: n/a"),
    }

    // Chunked prefill: a 224-token prompt joins a batch whose short
    // mate is already decoding. Monolithic prefill executes the whole
    // prompt inside one engine step, so the mate's inter-token gap
    // spikes by the full prefill cost; 32-token chunks spread it over 7
    // interleaved steps. The recorded ratio is the mate's max ITL,
    // monolithic over chunked (outputs are bit-identical either way —
    // the chunked-prefill purity invariant).
    let chunk_run = |chunk: usize| -> (f64, f64, u64) {
        let mut cfg = EngineConfig {
            mode: PathMode::TurboCpu,
            decode_threads: 2,
            ..Default::default()
        };
        cfg.batcher.prefill_chunk = chunk;
        let mut e = Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg);
        e.submit(GenRequest::new(0, b"short mate ".to_vec(), 48));
        for _ in 0..3 {
            e.step().expect("step");
        }
        let long: Vec<u8> = (0..224).map(|i| b'a' + (i % 13) as u8).collect();
        e.submit(GenRequest::new(1, long, 8));
        let mut last = std::time::Instant::now();
        let mut max_gap = 0.0f64;
        let mut long_ttft = 0.0f64;
        while !e.idle() {
            for ev in e.step().expect("step") {
                match ev.event {
                    TokenEvent::Token { .. } if ev.id == 0 => {
                        max_gap = max_gap.max(last.elapsed().as_secs_f64());
                        last = std::time::Instant::now();
                    }
                    TokenEvent::Finished(c) if ev.id == 1 => long_ttft = c.ttft,
                    _ => {}
                }
            }
        }
        (max_gap, long_ttft, e.metrics.prefill_chunks)
    };
    // Min over repetitions: scheduler noise only inflates a run's max
    // gap, so the smallest observation is the systematic stall.
    let chunk_best = |chunk: usize| -> (f64, f64, u64) {
        let mut best = (f64::INFINITY, f64::INFINITY, 0);
        for _ in 0..5 {
            let (g, t, c) = chunk_run(chunk);
            best = (best.0.min(g), best.1.min(t), c);
        }
        best
    };
    println!("\nchunked prefill (224-token late prompt vs decoding mate):");
    let (mono_gap, mono_ttft, mono_chunks) = chunk_best(0);
    let (chk_gap, chk_ttft, chk_chunks) = chunk_best(32);
    assert_eq!(mono_chunks, 0, "monolithic run crossed a chunk boundary");
    let itl_ratio = mono_gap / chk_gap.max(1e-12);
    println!(
        "  mate max ITL: monolithic {:.3}ms vs chunk=32 {:.3}ms \
         ({itl_ratio:.2}x flatter; {chk_chunks} boundaries crossed)",
        mono_gap * 1e3,
        chk_gap * 1e3
    );
    println!(
        "  long-prompt ttft: monolithic {:.3}ms vs chunk=32 {:.3}ms",
        mono_ttft * 1e3,
        chk_ttft * 1e3
    );

    if emit_json {
        let payload = format!(
            "{{\n  \"bench\": \"decode\",\n  \"kernel_backend\": \
             \"{backend}\",\n  \"geometry\": {{\"layers\": {L}, \
             \"heads\": {H}, \"d_head\": {DH}, \"block\": {BLOCK}}},\n  \
             \"cases\": {},\n  \"microkernel_vs_scalar\": [{}],\n  \
             \"kernel_vs_scalar\": [{}],\n  \
             \"thread_speedup_vs_t1\": [{}],\n  \
             \"sparse_topk\": [{}],\n  \
             \"shared_prefix\": [{}],\n  \"pool_cap\": {{\
             \"cap_bytes\": {POOL_CAP}, \"preemptions\": {preempts}, \
             \"replayed_tokens\": {replayed}, \
             \"memo_evictions\": {evicts}, \
             \"capped_over_uncapped\": {}}},\n  \
             \"chunked_prefill\": {{\"long_prompt_tokens\": 224, \
             \"chunk_tokens\": 32, \
             \"mate_max_itl_monolithic_s\": {mono_gap:e}, \
             \"mate_max_itl_chunked_s\": {chk_gap:e}, \
             \"itl_ratio_monolithic_over_chunked\": {itl_ratio:.4}, \
             \"long_ttft_monolithic_s\": {mono_ttft:e}, \
             \"long_ttft_chunked_s\": {chk_ttft:e}, \
             \"prefill_chunks\": {chk_chunks}}}\n}}\n",
            b.results_json(),
            micro_speedups.join(","),
            kernel_speedups.join(","),
            thread_speedups.join(","),
            sparse_json.join(","),
            shared_json.join(","),
            cap_overhead
                .map(|o| format!("{o:.4}"))
                .unwrap_or_else(|| "null".into())
        );
        std::fs::write("BENCH_decode.json", &payload)
            .expect("write BENCH_decode.json");
        println!("\nwrote BENCH_decode.json");
    }
}
