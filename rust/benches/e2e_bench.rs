//! End-to-end engine benchmarks over the real PJRT artifacts (Figure 7a's
//! serving content on this testbed) plus the Figure 1 timeshare via the
//! cost model. Requires `make artifacts`.

use turboattention::bench::Bencher;
use turboattention::coordinator::{Engine, EngineConfig, GenRequest, PathMode};
use turboattention::costmodel::{e2e_step_cost, GpuSpec, Method, ModelShape};
use turboattention::model::ModelBundle;
use turboattention::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    println!("== bench: engine decode step (real PJRT path) ==\n");
    for (name, mode) in [("turbo", PathMode::Turbo), ("flash", PathMode::Flash)] {
        let rt = Runtime::load("artifacts")?;
        let cfg = EngineConfig { mode, ..Default::default() };
        let mut engine = Engine::new(ModelBundle::new(rt), cfg);
        // Keep a long-lived request running; resubmit when the context
        // fills so every timed iteration is a real decode step.
        let mut next_id = 0u64;
        let mut refill = |e: &mut Engine| {
            if e.idle() {
                next_id += 1;
                e.submit(GenRequest::new(next_id, vec![b'a'; 96], 10_000));
                e.step().expect("prefill step"); // untimed prefill
            }
        };
        refill(&mut engine);
        let mut b = Bencher::quick();
        b.bench(&format!("decode step [{name}]"), || {
            refill(&mut engine);
            engine.step().expect("step")
        });
    }

    println!("\n== Figure 1a shape: attention share vs context (cost model) ==\n");
    let gpu = GpuSpec::a100_80gb();
    let shape = ModelShape::phi3_medium();
    for ctx in [1_000usize, 10_000, 40_000, 80_000] {
        let (attn, lin, tot) =
            e2e_step_cost(&gpu, &shape, &Method::FlashFp16, 1, ctx, true);
        println!(
            "ctx {ctx:>6}: attention {:>5.1}% of step ({:.1}ms attn, {:.1}ms linear)",
            100.0 * attn.total() / tot,
            attn.total() * 1e3,
            lin * 1e3
        );
    }
    Ok(())
}
