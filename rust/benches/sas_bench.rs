//! SAS microbenchmarks (Figure 5 + the §4 "softmax is 30% of attention"
//! claim): exact FP32 exp softmax vs SAS LUT+POLY softmax on the CPU
//! substrate, plus accuracy of the fit.

use turboattention::bench::Bencher;
use turboattention::sas::{softmax_row_exact, Sas};
use turboattention::testutil::Rng;

fn main() {
    println!("== bench: SAS softmax (Figure 5 / §4) ==\n");
    let mut rng = Rng::new(0);
    let rows = 256;
    let cols = 1024;
    let data: Vec<f32> = rng.normal_vec(rows * cols, 3.0);
    let sas = Sas::default();
    let mut b = Bencher::default();

    b.bench("softmax/exact-exp 256x1024", || {
        let mut m = data.clone();
        for r in 0..rows {
            softmax_row_exact(&mut m[r * cols..(r + 1) * cols]);
        }
        m
    });
    b.bench("softmax/SAS 256x1024", || {
        let mut m = data.clone();
        for r in 0..rows {
            sas.softmax_row(&mut m[r * cols..(r + 1) * cols]);
        }
        m
    });
    if let Some(s) = b.speedup("softmax/exact-exp 256x1024", "softmax/SAS 256x1024") {
        println!("\nSAS speedup over exact exp: {s:.2}x");
    }

    // Element-level exp throughput.
    let xs: Vec<f32> = (0..65536).map(|i| -(i as f32) / 11000.0).collect();
    b.bench("exp/libm 64k elems", || {
        xs.iter().map(|&x| x.exp()).sum::<f32>()
    });
    b.bench("exp/SAS 64k elems", || {
        xs.iter().map(|&x| sas.exp(x)).sum::<f32>()
    });
    if let Some(s) = b.speedup("exp/libm 64k elems", "exp/SAS 64k elems") {
        println!("\nSAS elementwise speedup over libm expf: {s:.2}x");
    }

    println!(
        "\naccuracy: poly max err on [0,1] = {:.2e}, SAS max err on [-6,0] = {:.2e}",
        {
            let mut w = 0.0f32;
            for i in 0..=1000 {
                let t = i as f32 / 1000.0;
                w = w.max((Sas::poly(t) - (-t).exp()).abs());
            }
            w
        },
        sas.max_abs_error(-6.0, 6000)
    );
}
