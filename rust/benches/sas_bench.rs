//! SAS microbenchmarks (Figure 5 + the §4 "softmax is 30% of attention"
//! claim): exact FP32 exp softmax vs SAS LUT+POLY softmax on the CPU
//! substrate — scalar `Sas::exp` vs the branch-free batched
//! `Sas::exp_block` the decode kernels use — plus accuracy of the fit.
//!
//! `exp_block` now dispatches to the selected kernel backend (scalar /
//! AVX2 / NEON); the `exp/SIMD-vs-scalar-arm` cases pit the dispatched
//! arm against the pinned scalar arm on identical inputs, isolating the
//! explicit vectorization. `--kernel-backend` / `TURBO_KERNEL` pin the
//! arm; the JSON records which one ran.
//!
//! `--json` writes every case and the computed speedups to
//! `BENCH_sas.json`.

use turboattention::bench::Bencher;
use turboattention::kernels;
use turboattention::sas::{softmax_row_exact, Sas};
use turboattention::testutil::Rng;
use turboattention::util::cli::Args;

/// Row softmax through the batched evaluator (max + `exp_block` + one
/// normalization pass) — the decode-loop shape.
fn softmax_row_block(sas: &Sas, row: &mut [f32]) {
    let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let sum = sas.exp_block(row, m);
    let inv = 1.0 / sum.max(1e-20);
    for v in row.iter_mut() {
        *v *= inv;
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let emit_json = args.flag("json");
    if let Some(kb) = args.opt("kernel-backend") {
        kernels::force_kernel_backend(kb).expect("--kernel-backend");
    }
    let backend = kernels::kernel_backend().name();
    println!("== bench: SAS softmax (Figure 5 / §4) ==\n");
    println!("kernel backend: {backend}\n");
    let mut rng = Rng::new(0);
    let rows = 256;
    let cols = 1024;
    let data: Vec<f32> = rng.normal_vec(rows * cols, 3.0);
    let sas = Sas::default();
    let mut b = Bencher::default();

    b.bench("softmax/exact-exp 256x1024", || {
        let mut m = data.clone();
        for r in 0..rows {
            softmax_row_exact(&mut m[r * cols..(r + 1) * cols]);
        }
        m
    });
    b.bench("softmax/SAS-scalar 256x1024", || {
        let mut m = data.clone();
        for r in 0..rows {
            sas.softmax_row(&mut m[r * cols..(r + 1) * cols]);
        }
        m
    });
    b.bench("softmax/SAS-block 256x1024", || {
        let mut m = data.clone();
        for r in 0..rows {
            softmax_row_block(&sas, &mut m[r * cols..(r + 1) * cols]);
        }
        m
    });
    let sas_vs_exact =
        b.speedup("softmax/exact-exp 256x1024", "softmax/SAS-block 256x1024");
    let block_vs_scalar_softmax = b.speedup(
        "softmax/SAS-scalar 256x1024",
        "softmax/SAS-block 256x1024",
    );
    if let Some(s) = sas_vs_exact {
        println!("\nSAS (block) speedup over exact exp: {s:.2}x");
    }
    if let Some(s) = block_vs_scalar_softmax {
        println!("exp_block speedup over scalar SAS softmax: {s:.2}x");
    }

    // Element-level exp throughput. Every case pays the same input
    // copy (exp_block mutates in place), so the speedups isolate the
    // exp itself.
    let xs: Vec<f32> = (0..65536).map(|i| -(i as f32) / 11000.0).collect();
    let mut buf = vec![0.0f32; xs.len()];
    b.bench("exp/libm 64k elems", || {
        buf.copy_from_slice(&xs);
        buf.iter().map(|&x| x.exp()).sum::<f32>()
    });
    b.bench("exp/SAS-scalar 64k elems", || {
        buf.copy_from_slice(&xs);
        buf.iter().map(|&x| sas.exp(x)).sum::<f32>()
    });
    b.bench("exp/SAS-block 64k elems", || {
        buf.copy_from_slice(&xs);
        sas.exp_block(&mut buf, 0.0)
    });
    let sas_vs_libm =
        b.speedup("exp/libm 64k elems", "exp/SAS-block 64k elems");
    let block_vs_scalar_exp =
        b.speedup("exp/SAS-scalar 64k elems", "exp/SAS-block 64k elems");
    if let Some(s) = sas_vs_libm {
        println!("\nSAS (block) elementwise speedup over libm expf: {s:.2}x");
    }
    if let Some(s) = block_vs_scalar_exp {
        println!("exp_block elementwise speedup over scalar exp: {s:.2}x");
    }

    // Dispatched arm vs pinned scalar arm on identical inputs — the
    // explicit-SIMD win inside exp_block itself (~1.0x by construction
    // when the process backend is scalar).
    b.bench("exp/dispatched-arm 64k elems", || {
        buf.copy_from_slice(&xs);
        sas.exp_block(&mut buf, 0.0)
    });
    b.bench("exp/scalar-arm 64k elems", || {
        buf.copy_from_slice(&xs);
        sas.exp_block_scalar(&mut buf, 0.0)
    });
    let arm_vs_scalar_arm =
        b.speedup("exp/scalar-arm 64k elems", "exp/dispatched-arm 64k elems");
    if let Some(s) = arm_vs_scalar_arm {
        println!("exp_block {backend} arm speedup over scalar arm: {s:.2}x");
    }

    let poly_err = {
        let mut w = 0.0f32;
        for i in 0..=1000 {
            let t = i as f32 / 1000.0;
            w = w.max((Sas::poly(t) - (-t).exp()).abs());
        }
        w
    };
    let sas_err = sas.max_abs_error(-6.0, 6000);
    println!(
        "\naccuracy: poly max err on [0,1] = {poly_err:.2e}, \
         SAS max err on [-6,0] = {sas_err:.2e}"
    );

    if emit_json {
        let opt = |v: Option<f64>| match v {
            Some(s) => format!("{s:.4}"),
            None => "null".to_string(),
        };
        let payload = format!(
            "{{\n  \"bench\": \"sas\",\n  \"kernel_backend\": \
             \"{backend}\",\n  \"cases\": {},\n  \"speedups\": \
             {{\"sas_block_vs_exact_softmax\": {}, \
             \"block_vs_scalar_softmax\": {}, \
             \"sas_block_vs_libm_exp\": {}, \
             \"block_vs_scalar_exp\": {}, \
             \"dispatched_arm_vs_scalar_arm\": {}}},\n  \
             \"accuracy\": {{\"poly_max_err\": {poly_err:e}, \
             \"sas_max_err\": {sas_err:e}}}\n}}\n",
            b.results_json(),
            opt(sas_vs_exact),
            opt(block_vs_scalar_softmax),
            opt(sas_vs_libm),
            opt(block_vs_scalar_exp),
            opt(arm_vs_scalar_arm)
        );
        std::fs::write("BENCH_sas.json", &payload)
            .expect("write BENCH_sas.json");
        println!("wrote BENCH_sas.json");
    }
}
