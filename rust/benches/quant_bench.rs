//! Quantization hot-path benchmarks (Figure 10 + §Perf targets): the
//! q2->q1 integer dequantization that dominates the decode path, packing,
//! and symmetric quantization throughput.

use turboattention::bench::Bencher;
use turboattention::kvcache::QuantPage;
use turboattention::quant::{
    pack_codes, quant_asym_int, quant_sym_int8, unpack_codes, Bits,
};
use turboattention::testutil::Rng;

fn main() {
    println!("== bench: FlashQ quantization hot paths ==\n");
    let mut b = Bencher::default();
    let mut rng = Rng::new(0);

    // Page-sized block: 64 tokens x 128 channels (paper tile).
    let tokens = 64;
    let channels = 128;
    let x = rng.normal_vec(tokens * channels, 1.0);
    let q1 = quant_sym_int8(&x);

    b.bench("quant_sym_int8 64x128", || quant_sym_int8(&x));
    b.bench("quant_asym_int4 64x128", || {
        quant_asym_int(&q1.codes, tokens, channels, Bits::Int4)
    });
    let blk4 = quant_asym_int(&q1.codes, tokens, channels, Bits::Int4);
    b.bench("pack int4 8k codes", || pack_codes(&blk4.codes, Bits::Int4));
    let packed = pack_codes(&blk4.codes, Bits::Int4);
    b.bench("unpack int4 8k codes", || unpack_codes(&packed));

    // The decode hot path: full page q2 -> q1.
    let page4 = QuantPage::from_q1(&q1.codes, tokens, channels, q1.scale, Bits::Int4);
    let page2 = QuantPage::from_q1(&q1.codes, tokens, channels, q1.scale, Bits::Int2);
    let mut scratch = Vec::new();
    let mut out = vec![0i8; tokens * channels];
    b.bench("page dequant q2->q1 int4 (hot path)", || {
        page4.dequant_q1_into(&mut scratch, &mut out);
        out[0]
    });
    b.bench("page dequant q2->q1 int2 (hot path)", || {
        page2.dequant_q1_into(&mut scratch, &mut out);
        out[0]
    });

    // Throughput summary for the hot path.
    let stats = b.results().iter().find(|r| r.name.contains("int4 (hot")).unwrap();
    let elems_per_s = (tokens * channels) as f64 / stats.mean_s();
    println!(
        "\nq2->q1 dequant throughput: {:.1} M elems/s ({} B page)",
        elems_per_s / 1e6,
        page4.bytes()
    );
}
