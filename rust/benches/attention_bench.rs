//! Attention engine benchmarks (Figure 6's latency content on this
//! testbed): CPU wall-clock of exact / flash / turbo engines across
//! context lengths, plus the analytical GPU-shape speedups.

use turboattention::attention::{
    attention_exact, flash_attention, turbo_attention, TurboConfig,
};
use turboattention::bench::{Bencher, Table};
use turboattention::costmodel::{
    attention_decode_cost, attention_prefill_cost, AttnWorkload, GpuSpec, Method,
};
use turboattention::tensor::Mat;
use turboattention::testutil::Rng;

fn main() {
    println!("== bench: attention engines (Figure 6 CPU substrate) ==\n");
    let mut b = Bencher::default();
    let mut rng = Rng::new(0);
    let d = 64;
    for n in [128usize, 256, 512] {
        let q = Mat::randn(&mut rng, n, d, 1.0);
        let k = Mat::randn(&mut rng, n, d, 1.0);
        let v = Mat::randn(&mut rng, n, d, 1.0);
        b.bench(&format!("exact n={n}"), || {
            attention_exact(&q, &k, &v, true)
        });
        b.bench(&format!("flash n={n}"), || {
            flash_attention(&q, &k, &v, 64, 64, true)
        });
        let cfg = TurboConfig { br: 64, bc: 64, causal: true, ..Default::default() };
        b.bench(&format!("turbo n={n}"), || {
            turbo_attention(&q, &k, &v, &cfg)
        });
    }

    println!("\n== analytical A100 speedups (Figure 6 shape) ==\n");
    let gpu = GpuSpec::a100_80gb();
    let mut t = Table::new(&["phase", "ctx", "KIVI-4", "GEAR-4", "Turbo-3"]);
    for prefill in [true, false] {
        for ctx in [4_000usize, 8_000, 16_000, 32_000] {
            let w = AttnWorkload {
                batch: 4,
                heads: 40,
                d_head: 128,
                nq: if prefill { ctx } else { 1 },
                nk: ctx,
            };
            let cost = |m: &Method| {
                if prefill {
                    attention_prefill_cost(&gpu, m, &w).total()
                } else {
                    attention_decode_cost(&gpu, m, &w).total()
                }
            };
            let base = cost(&Method::FlashFp16);
            t.row(&[
                if prefill { "prefill" } else { "decode" }.into(),
                format!("{ctx}"),
                format!("{:.2}x", base / cost(&Method::Kivi { bits: 4 })),
                format!("{:.2}x", base / cost(&Method::GearL { bits: 4, rank: 4 })),
                format!("{:.2}x", base / cost(&Method::Turbo { avg_bits: 3.0 })),
            ]);
        }
    }
    t.print();
    println!("\n(paper: Turbo up to 1.8x prefill / 1.7x decode; KIVI/GEAR < 1x decode)");
}
