//! Long-context memory study: drive one request to the model's full
//! context, tracking quantized-cache growth vs the FP16 equivalent, and
//! project the same accounting onto the paper's Phi3-medium/A100 shape
//! (the Figure 6 "FP16 OOM beyond 4k" claim).
//!
//! Run: `cargo run --release --example longcontext`

use anyhow::Result;
use turboattention::coordinator::{
    Engine, EngineConfig, GenRequest, PathMode, SamplingParams,
};
use turboattention::costmodel::{max_batch, GpuSpec, Method, ModelShape};
use turboattention::model::{ModelBundle, Sampler};
use turboattention::quant::Bits;
use turboattention::runtime::Runtime;

fn main() -> Result<()> {
    // Part 1: real engine, real cache accounting, context filled to max.
    let rt = Runtime::load("artifacts")?;
    let max_ctx = rt.manifest.model.max_ctx;
    let cfg = EngineConfig {
        mode: PathMode::Turbo,
        kv_bits: Bits::Int4,
        n_2bit_heads: 2, // mixed precision: 2 of 4 heads at 2-bit
        ..Default::default()
    };
    let mut engine = Engine::new(ModelBundle::new(rt), cfg);
    let prompt = b"the cache streams old blocks per layer. ".to_vec();
    let gen = max_ctx - prompt.len() - 2; // fill the context
    let params = SamplingParams {
        sampler: Sampler::TopK { k: 6, temp: 0.9 },
        max_new_tokens: gen,
        ..Default::default()
    };
    engine.submit(GenRequest::with_params(1, prompt, params));
    let done = engine.run_to_completion()?;
    let c = &done[0];
    println!(
        "generated {} tokens to context {}/{max_ctx} ({:?})",
        c.generated.len(),
        c.prompt_len + c.generated.len(),
        c.finish_reason
    );
    println!(
        "quantized cache: {} bytes, {:.2}x smaller than FP16 equivalent",
        engine.metrics.cache_bytes, engine.metrics.cache_compression
    );

    // Part 2: the same accounting at paper scale (analytical).
    println!("\nPhi3-medium on A100-80GB — max batch before KV OOM:");
    let gpu = GpuSpec::a100_80gb();
    let shape = ModelShape::phi3_medium();
    println!("{:<14} {:>8} {:>8} {:>8} {:>8}", "method", "4k", "8k", "16k", "32k");
    for m in [
        Method::FlashFp16,
        Method::Kivi { bits: 4 },
        Method::Turbo { avg_bits: 3.0 },
    ] {
        let row: Vec<String> = [4_000usize, 8_000, 16_000, 32_000]
            .iter()
            .map(|&ctx| format!("{}", max_batch(&gpu, &shape, &m, ctx)))
            .collect();
        println!(
            "{:<14} {:>8} {:>8} {:>8} {:>8}",
            m.label(),
            row[0],
            row[1],
            row[2],
            row[3]
        );
    }
    println!(
        "\n(paper Figure 6: FP16 OOMs at batch 4 beyond 4k context; the \
         int-4/2 cache sustains 32k)"
    );
    Ok(())
}
