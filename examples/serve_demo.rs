//! End-to-end serving driver (the mandated full-system validation).
//!
//! Loads the trained tiny LM through the PJRT runtime, starts the engine
//! with the continuous batcher, replays a Poisson workload of generation
//! requests through the *real* serving path (prefill -> paged quantized
//! KV cache -> per-step decode with q2->q1 integer dequantization), and
//! reports latency percentiles, token throughput, and cache compression —
//! the serving-paper analogue of the paper's §5.5 efficiency study.
//!
//! Run: `cargo run --release --example serve_demo [-- --requests 48]`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::time::Instant;

use anyhow::Result;
use turboattention::coordinator::{
    Engine, EngineConfig, GenRequest, PathMode, SamplingParams, TokenEvent,
};
use turboattention::metrics::Histogram;
use turboattention::model::{ModelBundle, Sampler};
use turboattention::runtime::Runtime;
use turboattention::util::cli::Args;
use turboattention::workload::{Arrivals, WorkloadSpec};

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.opt_parse("requests", 32usize);
    let spec = WorkloadSpec {
        arrivals: Arrivals::Poisson { rate: args.opt_parse("rate", 4.0f64) },
        n_requests,
        prompt_len: (48, 192),
        gen_len: (16, 48),
        seed: args.opt_parse("seed", 7u64),
    };
    let trace = spec.generate();
    println!(
        "serve_demo: {n_requests} requests, Poisson arrivals, prompts 48-192B, gen 16-48 tokens\n"
    );

    for (name, mode) in [("turbo", PathMode::Turbo), ("flash-exact", PathMode::Flash)] {
        let rt = Runtime::load("artifacts")?;
        let cfg = EngineConfig { mode, ..Default::default() };
        let mut engine = Engine::new(ModelBundle::new(rt), cfg);
        // Per-request sampling: seed each request by its trace index so
        // the replay is reproducible request-by-request, whatever the
        // batch composition at replay time.
        let req_params = |idx: usize, max_new: usize| SamplingParams {
            sampler: Sampler::TopK { k: 4, temp: 0.7 },
            seed: idx as u64,
            stop_byte: None,
            max_new_tokens: max_new,
        };

        // Replay the trace against the engine's iteration loop: submit
        // requests whose arrival time has passed, then step.
        let t0 = Instant::now();
        let mut next = 0usize;
        let mut ttft = Histogram::new();
        let mut total = Histogram::new();
        let mut tokens = 0u64;
        let mut completed = 0usize;
        while completed < trace.len() {
            let now = t0.elapsed().as_secs_f64();
            while next < trace.len() && trace[next].at <= now {
                let e = &trace[next];
                engine.submit(GenRequest::with_params(
                    next as u64,
                    e.prompt.clone(),
                    req_params(next, e.max_new_tokens),
                ));
                next += 1;
            }
            if engine.idle() {
                // Nothing admitted yet: fast-forward to the next arrival.
                if next < trace.len() {
                    let e = &trace[next];
                    engine.submit(GenRequest::with_params(
                        next as u64,
                        e.prompt.clone(),
                        req_params(next, e.max_new_tokens),
                    ));
                    next += 1;
                }
                continue;
            }
            for ev in engine.step()? {
                if let TokenEvent::Finished(c) = ev.event {
                    ttft.record(c.ttft);
                    total.record(c.total_latency);
                    tokens += c.generated.len() as u64;
                    completed += 1;
                }
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!("== {name} ==");
        // Which integer-kernel ISA produced these numbers (scalar |
        // avx2 | neon) — throughput comparisons are meaningless
        // without it.
        println!("  kernel backend: {}", engine.metrics.kernel_backend);
        println!("  ttft : {}", ttft.summary());
        println!("  itl  : {}", engine.itl_hist.summary());
        println!("  e2e  : {}", total.summary());
        println!(
            "  throughput: {:.1} tokens/s over {:.1}s wall ({} tokens, {} requests)",
            tokens as f64 / wall,
            wall,
            tokens,
            completed
        );
        if engine.metrics.cache_compression > 0.0 {
            println!(
                "  kv cache: {:.2}x compressed vs FP16 equivalent",
                engine.metrics.cache_compression
            );
        }
        println!();
    }
    println!(
        "note: CPU-interpret kernels — absolute times are not GPU claims; \
         the GPU-shape claims live in `turboattn experiment fig6|fig7a`."
    );
    Ok(())
}
