//! Library-client streaming: drive a running engine through
//! `EngineHandle` — submit, stream `TokenEvent`s as they decode, cancel
//! a request mid-flight, and read a stats snapshot. Runs on the
//! artifact-free TurboCpu path (no PJRT toolchain needed).
//!
//! Run: `cargo run --release --example streaming_client`

use std::io::Write as _;
use std::sync::mpsc::channel;

use anyhow::Result;
use turboattention::coordinator::{
    Engine, EngineConfig, EngineHandle, GenRequest, PathMode, SamplingParams,
    TokenEvent,
};
use turboattention::model::{ByteTokenizer, ModelBundle, Sampler};
use turboattention::runtime::Runtime;

fn main() -> Result<()> {
    // Engine thread: the handle is the only thing clients touch.
    let (tx, rx) = channel();
    let engine_thread = std::thread::spawn(move || {
        let cfg =
            EngineConfig { mode: PathMode::TurboCpu, ..Default::default() };
        Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg)
            .run_loop(rx)
    });
    let handle = EngineHandle::new(tx);
    let tok = ByteTokenizer;

    // 1. Stream a request token by token (sampling is per-request: the
    //    same prompt + params reproduces this stream exactly, whatever
    //    else is batched alongside).
    let params = SamplingParams {
        sampler: Sampler::TopK { k: 6, temp: 0.8 },
        seed: 11,
        stop_byte: None,
        max_new_tokens: 48,
    };
    let mut resp = handle
        .submit(GenRequest::with_params(0, tok.encode("the stream "), params))?;
    println!("request {} admitted", resp.id());
    while let Some(ev) = resp.recv() {
        match ev {
            TokenEvent::First { token, ttft } => {
                print!("[ttft {:.1}ms] {}", ttft * 1e3, tok.decode(&[token]));
                std::io::stdout().flush().ok();
            }
            TokenEvent::Token { token, .. } => {
                print!("{}", tok.decode(&[token]));
                std::io::stdout().flush().ok();
            }
            TokenEvent::Finished(c) => {
                println!(
                    "\nfinished: {:?} after {} tokens ({:.1} ms total)",
                    c.finish_reason,
                    c.generated.len(),
                    c.total_latency * 1e3
                );
            }
        }
    }

    // 2. Cancel a long request after its first token: the engine frees
    //    its batcher slot and KV pages immediately, and the stream
    //    still terminates with a `Cancelled` completion.
    let mut long = handle.submit(GenRequest::with_params(
        0,
        tok.encode("cancel me "),
        SamplingParams::greedy(200),
    ))?;
    if matches!(long.recv(), Some(TokenEvent::First { .. })) {
        long.cancel()?;
    }
    if let Some(c) = long.wait() {
        println!(
            "request {} {:?} after {} of 200 tokens",
            c.id,
            c.finish_reason,
            c.generated.len()
        );
    }

    let stats = handle.stats()?;
    println!(
        "engine: {} completed, {} cancelled | itl {}",
        stats.metrics.requests_completed,
        stats.metrics.requests_cancelled,
        stats.itl.summary()
    );

    handle.shutdown();
    engine_thread.join().expect("engine thread")?;
    Ok(())
}
