//! Thin TCP streaming client over the crate's single wire-protocol
//! implementation ([`turboattention::loadgen::client`]): spawn an
//! engine + server on a loopback port (the same wiring `turboattn
//! serve` does), then drive `GEN → ACK/TOK…/DONE`, a mid-stream
//! `CANCEL`, and a machine-readable `STATS JSON` scrape as an external
//! client would. Runs on the artifact-free TurboCpu path (no PJRT
//! toolchain needed).
//!
//! Run: `cargo run --release --example streaming_client`

use std::io::Write as _;
use std::net::TcpListener;
use std::sync::mpsc::channel;

use anyhow::Result;
use turboattention::coordinator::{
    Engine, EngineConfig, EngineHandle, PathMode, SamplingParams,
};
use turboattention::loadgen::{TcpClient, WireEvent};
use turboattention::model::{ByteTokenizer, ModelBundle, Sampler};
use turboattention::runtime::Runtime;
use turboattention::server;

fn main() -> Result<()> {
    // Engine thread + TCP listener on an ephemeral port.
    let (tx, rx) = channel();
    let engine_thread = std::thread::spawn(move || {
        let cfg =
            EngineConfig { mode: PathMode::TurboCpu, ..Default::default() };
        Engine::new(ModelBundle::new(Runtime::cpu_substrate()), cfg)
            .run_loop(rx)
    });
    let handle = EngineHandle::new(tx);
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    {
        let h = handle.clone();
        std::thread::spawn(move || {
            let _ = server::serve(listener, h, SamplingParams::default());
        });
    }

    let tok = ByteTokenizer;
    let mut client = TcpClient::connect(addr)?;

    // 1. Stream a request token by token (sampling rides the GEN line:
    //    the same prompt + overrides reproduces this stream exactly,
    //    whatever else the server is batching).
    let params = SamplingParams {
        sampler: Sampler::TopK { k: 6, temp: 0.8 },
        seed: 11,
        stop_byte: None,
        max_new_tokens: 48,
    };
    let id = client.gen(&tok.encode("the stream "), &params, 0)?;
    println!("request {id} admitted");
    loop {
        match client.next_event()? {
            WireEvent::Tok { byte, .. } => {
                print!("{}", tok.decode(&[byte]));
                std::io::stdout().flush().ok();
            }
            WireEvent::Done { reason, ttft_ms, total_ms, .. } => {
                println!(
                    "\nfinished: {reason} (ttft {ttft_ms:.1} ms, \
                     {total_ms:.1} ms total)"
                );
                break;
            }
            other => anyhow::bail!("unexpected reply: {other:?}"),
        }
    }

    // 2. Cancel a long request after its first token: the engine frees
    //    its batcher slot and KV pages immediately, and the stream
    //    still terminates with a `DONE .. cancelled` line.
    let id = client.gen(
        &tok.encode("cancel me "),
        &SamplingParams::greedy(200),
        0,
    )?;
    let mut streamed = 0usize;
    loop {
        match client.next_event()? {
            WireEvent::Tok { .. } => {
                streamed += 1;
                if streamed == 1 {
                    client.cancel(id)?;
                }
            }
            WireEvent::Done { reason, .. } => {
                println!(
                    "request {id} {reason} after {streamed} of 200 tokens"
                );
                break;
            }
            other => anyhow::bail!("unexpected reply: {other:?}"),
        }
    }

    // 3. Machine-readable stats — no fragile text parsing.
    let stats = client.stats_json()?;
    let get = |k: &str| stats.get(k).cloned().unwrap_or_default();
    println!(
        "engine: {} completed, {} cancelled | itl p50 {} ms | kernel {}",
        get("completed"),
        get("cancelled"),
        get("itl_p50_ms"),
        get("kernel")
    );

    client.quit()?;
    handle.shutdown();
    engine_thread.join().expect("engine thread")?;
    Ok(())
}
