//! Accuracy sweep: how each KV-compression method degrades with bit
//! width, on calibrated outlier-structured QKV (the Table 2 machinery,
//! exposed as a library-usage example).
//!
//! Run: `cargo run --release --example accuracy_sweep`

use turboattention::experiments::accuracy::{AccMethod, Suite};
use turboattention::quant::Bits;

fn main() {
    let suite = Suite::build("sweep", 160, 3);
    let exact = suite.exact_outputs();

    println!("method                bits   agreement%");
    println!("--------------------  ----   ----------");
    let cases: Vec<(String, AccMethod)> = vec![
        ("TurboAttention".into(), AccMethod::turbo_uniform(Bits::Int8, 32, 32)),
        ("TurboAttention".into(), AccMethod::turbo_uniform(Bits::Int4, 32, 32)),
        ("TurboAttention".into(), AccMethod::turbo_uniform(Bits::Int3, 32, 32)),
        ("TurboAttention".into(), AccMethod::turbo_uniform(Bits::Int2, 32, 32)),
        ("KIVI".into(), AccMethod::Kivi { bits: 4 }),
        ("KIVI".into(), AccMethod::Kivi { bits: 2 }),
        ("GEAR-L r=4".into(), AccMethod::Gear { bits: 4, rank: 4 }),
        ("GEAR-L r=4".into(), AccMethod::Gear { bits: 2, rank: 4 }),
    ];
    let bits_label = ["8", "4", "3", "2", "4", "2", "4", "2"];
    for ((name, m), bits) in cases.iter().zip(bits_label) {
        let acc = suite.agreement(&exact, &m.run(&suite));
        println!("{name:<20}  {bits:>4}   {acc:>9.2}");
    }
    println!(
        "\nexpected shape (paper Table 2): Turbo-4bit near-lossless, \
         graceful 3-bit, degraded 2-bit; KIVI hit hardest by the value-\
         cache channel outliers."
    );
}
