//! Quickstart: load the AOT artifacts, generate a completion on the
//! TurboAttention path, and compare it with the exact FlashAttention
//! baseline on the same prompt.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`)

use anyhow::Result;
use turboattention::coordinator::{Engine, EngineConfig, GenRequest, PathMode};
use turboattention::model::{ByteTokenizer, ModelBundle};
use turboattention::runtime::Runtime;

fn main() -> Result<()> {
    let tok = ByteTokenizer;
    let prompt = "the scheduler ";
    let mut outputs = Vec::new();

    for (name, mode) in [("turbo", PathMode::Turbo), ("flash", PathMode::Flash)] {
        let rt = Runtime::load("artifacts")?;
        let cfg = EngineConfig { mode, ..Default::default() };
        let mut engine = Engine::new(ModelBundle::new(rt), cfg);
        engine.submit(GenRequest::new(1, tok.encode(prompt), 48));
        let done = engine.run_to_completion()?;
        let c = &done[0];
        println!(
            "[{name}] \"{prompt}{}\"",
            tok.decode(&c.generated)
        );
        println!(
            "[{name}] ttft {:.0}ms, {:.1}ms/token, cache compression {:.2}x",
            c.ttft * 1e3,
            c.tpot * 1e3,
            engine.metrics.cache_compression.max(1.0)
        );
        outputs.push(c.generated.clone());
    }

    let agree = outputs[0]
        .iter()
        .zip(&outputs[1])
        .filter(|(a, b)| a == b)
        .count() as f64
        / outputs[0].len().max(1) as f64;
    println!(
        "\ngreedy agreement turbo vs exact: {:.0}% ({} tokens)",
        agree * 100.0,
        outputs[0].len()
    );
    Ok(())
}
